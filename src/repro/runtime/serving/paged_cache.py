"""Host-side page-pool bookkeeping for the continuous-batching scheduler.

The device state is ONE shared pool per layer (``models.attention.
init_paged_pool``); this class owns the free list, per-page refcounts, the
per-slot block tables and lengths, and the admission-time zeroing. The
leak-freedom contract lives at the ``alloc`` boundary: a slot's *fresh*
pages are zeroed in-kernel (``kernels/paged_attention`` ``paged_reset``)
before the slot's table row is published, so no read path ever observes a
previous tenant's K/V — recycling is safe by construction, not by
cache-lifetime discipline (the serving analogue of the paper's R2 state
isolation).

Prefix sharing rides on two additions, both scoped so the R2 analogue
survives intact:

* **Per-page refcounts.** A page may appear in several slots' tables at
  once (read-only prompt-prefix pages); ``release`` decrements and only
  returns a page to the free list at zero, so a shared page can never be
  recycled — and hence never re-zeroed or rewritten — while any reader
  still maps it.
* **A per-tenant prefix index.** Full prompt pages are keyed by a chained
  SHA-256 over their token content, *with the tenant id baked into the
  lookup key*: a request can only ever be handed pages whose content was
  written under its own tenant. Cross-tenant sharing is impossible at the
  data-structure level, not by scheduler politeness — the adversarial test
  probes exactly this (identical prompt, different tenant, must get fresh
  zeroed pages and bitwise fresh-cache logits). The index holds its own
  refcount on each entry, so prompt pages of *recently finished* requests
  stay shareable until pool pressure evicts them (LRU).

Copy-on-write is by construction rather than by fault: sharing is page
granular, a sharer's write cursor starts at the shared-page boundary, and
every page past that boundary is a fresh zeroed page allocated at
admission — so no write can ever land on a shared page.

Speculative decoding adds a parallel *draft* pool (same page-id space, same
tables/lengths/refcounts — only the K/V arrays differ, sized for the draft
model): admission zeroing and rejected-tail ``rollback`` are applied to
both pools in lockstep, so the draft cache inherits every isolation
property of the target cache for free.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Optional, Sequence

import numpy as np

from repro.kernels.paged_attention import ops as paged_ops


class PagePool:
    """Refcounted free-list allocator over a device page pool + per-slot
    block tables, with an optional same-tenant prefix index and an optional
    parallel draft pool.

    ``tables`` rows are padded with the slot's own first page (the reset is
    idempotent over duplicates), so a short request never holds a reserved
    sentinel page and the table array stays rectangular for the one compiled
    graph."""

    def __init__(self, model, *, n_slots: int, n_pages: int, page_size: int,
                 pages_per_slot: int, draft_model=None,
                 prefix_index: bool = False):
        if model.init_paged_cache is None:
            raise ValueError(
                f"{model.cfg.name} ({model.cfg.family}) has no paged serving "
                f"path; continuous batching needs a transformer-family model")
        self.page_size = page_size
        self.n_pages = n_pages
        self.pages = model.init_paged_cache(n_pages, page_size)
        # draft pool: same page ids, draft-sized K/V. Shared-prefix pages are
        # populated for BOTH pools during the original request's prefill, so
        # a sharer admitted later finds its draft cache warm too.
        self.draft_pages = (None if draft_model is None else
                            draft_model.init_paged_cache(n_pages, page_size))
        self.free: list[int] = list(range(n_pages - 1, -1, -1))
        self.refcount = np.zeros((n_pages,), np.int32)
        self.tables = np.zeros((n_slots, pages_per_slot), np.int32)
        self.lengths = np.zeros((n_slots,), np.int32)
        self._owned: list[list[int]] = [[] for _ in range(n_slots)]
        self._shared: list[list[int]] = [[] for _ in range(n_slots)]
        # (tenant, chained sha256 of page tokens) -> page id, LRU-ordered.
        # The tenant id in the key IS the cross-tenant barrier.
        self.prefix_index_enabled = prefix_index
        self._prefix_index: OrderedDict[tuple, int] = OrderedDict()
        # slot -> [pages hashed so far, running digest] for incremental
        # registration across prefill chunks
        self._reg: dict[int, list] = {}

    @property
    def free_pages(self) -> int:
        return len(self.free)

    # ------------------------------------------------------------- reset glue
    def _reset_rows(self, row: np.ndarray) -> None:
        """Zero ``row``'s pages in-kernel in the target pool and (when
        present) the draft pool. Pools are consumed and rebound."""
        self.pages = dict(zip(
            ("k_pages", "v_pages"),
            paged_ops.paged_reset(self.pages["k_pages"],
                                  self.pages["v_pages"], row)))
        if self.draft_pages is not None:
            self.draft_pages = dict(zip(
                ("k_pages", "v_pages"),
                paged_ops.paged_reset(self.draft_pages["k_pages"],
                                      self.draft_pages["v_pages"], row)))

    # ----------------------------------------------------------------- alloc
    def alloc(self, slot: int, n: int, shared: Sequence[int] = ()) -> bool:
        """Claim ``n`` pages for ``slot``: map the ``shared`` prefix pages
        read-only (refcount bump, NO zeroing — their content is the point)
        and zero ``n - len(shared)`` fresh pages in-kernel. False when the
        pool can't satisfy the claim even after LRU-evicting idle index
        entries (caller retries next step).

        ``shared`` must come from ``prefix_lookup`` in the same scheduler
        step (no yield between lookup and alloc), so the entries still hold
        their index refcount and cannot have been recycled in between."""
        shared = list(shared)
        fresh_n = n - len(shared)
        assert fresh_n >= 1, "a slot needs at least one writable fresh page"
        if n > self.tables.shape[1]:
            return False
        if fresh_n > len(self.free) and not self._evict(fresh_n):
            return False
        assert not self._owned[slot], f"slot {slot} already holds pages"
        fresh = [self.free.pop() for _ in range(fresh_n)]
        pages = shared + fresh
        width = self.tables.shape[1]
        row = np.full((width,), pages[0], np.int32)
        row[:n] = pages
        # zero BEFORE publishing the table row — but only the FRESH pages:
        # shared pages carry the prefix K/V the sharer is here for, and
        # zeroing them would corrupt every other reader. The reset row stays
        # full-width (padded with fresh[0]; idempotent duplicates) so one
        # compiled reset graph serves every allocation shape.
        reset_row = np.full((width,), fresh[0], np.int32)
        reset_row[:fresh_n] = fresh
        self._reset_rows(reset_row)
        for p in shared:
            self.refcount[p] += 1
        self.refcount[fresh] = 1
        self.tables[slot] = row
        # the write cursor starts at the shared boundary: everything before
        # it is read-only by construction (the COW rule, enforced by where
        # fresh pages begin rather than by trapping writes)
        self.lengths[slot] = len(shared) * self.page_size
        self._owned[slot] = pages
        self._shared[slot] = shared
        return True

    def _evict(self, fresh_n: int) -> bool:
        """LRU-evict idle prefix-index entries (refcount 1 = held only by
        the index) until ``fresh_n`` pages are free. Entries still mapped by
        a live slot are skipped (rotated to MRU). True on success."""
        for _ in range(len(self._prefix_index)):
            if fresh_n <= len(self.free):
                break
            key, page = next(iter(self._prefix_index.items()))
            if self.refcount[page] == 1:
                del self._prefix_index[key]
                self.refcount[page] = 0
                self.free.append(page)
            else:
                self._prefix_index.move_to_end(key)
        return fresh_n <= len(self.free)

    # --------------------------------------------------------------- release
    def release(self, slot: int) -> None:
        """Drop the slot's references; pages return to the free list only at
        refcount zero. Prompt pages registered in the prefix index keep the
        index's own reference, so a recently-finished request's prefix stays
        shareable (until LRU eviction under pressure). Freed page *contents*
        stay on device until the next tenant's admission zeroes them — which
        is exactly what the adversarial recycling test probes."""
        for p in self._owned[slot]:
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self.free.append(p)
        self._owned[slot] = []
        self._shared[slot] = []
        self._reg.pop(slot, None)
        self.tables[slot] = 0
        self.lengths[slot] = 0

    # ---------------------------------------------------------- prefix index
    def _page_digest(self, digest: bytes, tokens) -> bytes:
        chunk = np.ascontiguousarray(np.asarray(tokens, np.int32))
        return hashlib.sha256(digest + chunk.tobytes()).digest()

    def prefix_lookup(self, tenant: Optional[str], prompt) -> list[int]:
        """Longest run of full prompt pages already cached *for this
        tenant*, in order. Capped one page short of both the prompt and the
        table width, so the admitted request always has at least one fresh
        page and at least one prompt token left to prefill (the first
        generated token needs a real query position)."""
        if not self.prefix_index_enabled:
            return []
        P = self.page_size
        cap = min((len(prompt) - 1) // P, self.tables.shape[1] - 1)
        digest, hit = b"", []
        for j in range(cap):
            digest = self._page_digest(digest, prompt[j * P:(j + 1) * P])
            page = self._prefix_index.get((tenant, digest))
            if page is None:
                break
            self._prefix_index.move_to_end((tenant, digest))
            hit.append(page)
        return hit

    def register_prefix(self, slot: int, tenant: Optional[str], prompt,
                        n_done: int) -> None:
        """Publish the slot's fully-prefilled full prompt pages into the
        tenant's prefix index (incremental across chunks: the chained digest
        is carried per slot). Idempotent; existing keys are refreshed to MRU
        but never re-pointed, so concurrent identical prompts converge on
        one canonical page per prefix."""
        if not self.prefix_index_enabled:
            return
        P = self.page_size
        max_j = min(int(n_done), len(prompt)) // P
        st = self._reg.setdefault(slot, [0, b""])
        while st[0] < max_j:
            j = st[0]
            st[1] = self._page_digest(st[1], prompt[j * P:(j + 1) * P])
            key = (tenant, st[1])
            if key in self._prefix_index:
                self._prefix_index.move_to_end(key)
            else:
                page = self._owned[slot][j]
                self._prefix_index[key] = page
                self.refcount[page] += 1
            st[0] += 1

    # -------------------------------------------------------------- rollback
    def rollback(self, slot: int, start: int, end: int) -> None:
        """Zero logical token positions ``[start, end)`` of the slot's
        sequence in-kernel, in both pools (the speculative rejected-tail
        eraser). The range must lie past the shared prefix — rejected
        speculation starts at the verified length, which is always past the
        prompt, let alone the shared span — so shared pages are untouchable
        here by construction (and asserted)."""
        if end <= start:
            return
        assert start >= len(self._shared[slot]) * self.page_size
        assert end <= len(self._owned[slot]) * self.page_size
        row = self.tables[slot]
        self.pages = dict(zip(
            ("k_pages", "v_pages"),
            paged_ops.paged_rollback(self.pages["k_pages"],
                                     self.pages["v_pages"], row, start, end)))
        if self.draft_pages is not None:
            self.draft_pages = dict(zip(
                ("k_pages", "v_pages"),
                paged_ops.paged_rollback(self.draft_pages["k_pages"],
                                         self.draft_pages["v_pages"], row,
                                         start, end)))

    # ---------------------------------------------------------------- probes
    def slot_pages(self, slot: int) -> list[int]:
        """Physical page ids currently owned by ``slot`` (for tests/probes)."""
        return list(self._owned[slot])

    def slot_shared_pages(self, slot: int) -> list[int]:
        """The read-only shared-prefix subset of ``slot_pages`` (probes)."""
        return list(self._shared[slot])

    def check_invariants(self) -> None:
        """Refcount accounting must balance exactly: every page's refcount
        equals its number of slot owners plus its index membership; the free
        list is exactly the refcount-zero pages, without duplicates."""
        expect = np.zeros((self.n_pages,), np.int32)
        for owned in self._owned:
            for p in owned:
                expect[p] += 1
        for p in self._prefix_index.values():
            expect[p] += 1
        assert np.array_equal(self.refcount, expect), \
            (self.refcount.tolist(), expect.tolist())
        assert len(set(self.free)) == len(self.free), "duplicate free pages"
        assert sorted(self.free) == sorted(np.flatnonzero(expect == 0).tolist())
