"""Host-side page-pool bookkeeping for the continuous-batching scheduler.

The device state is ONE shared pool per layer (``models.attention.
init_paged_pool``); this class owns the free list, the per-slot block tables
and lengths, and the admission-time zeroing. The leak-freedom contract lives
at the ``alloc`` boundary: a slot's pages are zeroed *in-kernel*
(``kernels/paged_attention`` ``paged_reset``) before the slot's table row is
published, so no read path ever observes a previous tenant's K/V —
recycling is safe by construction, not by cache-lifetime discipline (the
serving analogue of the paper's R2 state isolation).
"""
from __future__ import annotations

import numpy as np

from repro.kernels.paged_attention import ops as paged_ops


class PagePool:
    """Free-list allocator over a device page pool + per-slot block tables.

    ``tables`` rows are padded with the slot's own first page (the reset is
    idempotent over duplicates), so a short request never holds a reserved
    sentinel page and the table array stays rectangular for the one compiled
    graph."""

    def __init__(self, model, *, n_slots: int, n_pages: int, page_size: int,
                 pages_per_slot: int):
        if model.init_paged_cache is None:
            raise ValueError(
                f"{model.cfg.name} ({model.cfg.family}) has no paged serving "
                f"path; continuous batching needs a transformer-family model")
        self.page_size = page_size
        self.n_pages = n_pages
        self.pages = model.init_paged_cache(n_pages, page_size)
        self.free: list[int] = list(range(n_pages - 1, -1, -1))
        self.tables = np.zeros((n_slots, pages_per_slot), np.int32)
        self.lengths = np.zeros((n_slots,), np.int32)
        self._owned: list[list[int]] = [[] for _ in range(n_slots)]

    @property
    def free_pages(self) -> int:
        return len(self.free)

    def alloc(self, slot: int, n: int) -> bool:
        """Claim ``n`` pages for ``slot`` and zero them in-kernel. False when
        the pool can't satisfy the claim (caller retries next step)."""
        if n > len(self.free) or n > self.tables.shape[1]:
            return False
        assert not self._owned[slot], f"slot {slot} already holds pages"
        pages = [self.free.pop() for _ in range(n)]
        row = np.full((self.tables.shape[1],), pages[0], np.int32)
        row[:n] = pages
        # zero BEFORE publishing the table row: the pools are consumed and
        # rebound (the Pallas path writes in place via donation). The full
        # padded row keeps one compiled reset graph; re-zeroing the padding
        # duplicates is idempotent.
        self.pages = dict(zip(
            ("k_pages", "v_pages"),
            paged_ops.paged_reset(self.pages["k_pages"],
                                  self.pages["v_pages"], row)))
        self.tables[slot] = row
        self.lengths[slot] = 0
        self._owned[slot] = pages
        return True

    def release(self, slot: int) -> None:
        """Return the slot's pages to the free list. The page *contents* stay
        on device until the next tenant's admission zeroes them — which is
        exactly what the adversarial recycling test probes."""
        self.free.extend(self._owned[slot])
        self._owned[slot] = []
        self.tables[slot] = 0
        self.lengths[slot] = 0

    def slot_pages(self, slot: int) -> list[int]:
        """Physical page ids currently owned by ``slot`` (for tests/probes)."""
        return list(self._owned[slot])
