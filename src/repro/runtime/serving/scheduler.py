"""Continuous-batching scheduler over the slot-recycled paged KV cache.

Contrast with ``runtime.server.WaveServer`` (the measured baseline): instead
of draining a whole same-length wave before touching the queue, the
scheduler revisits admission at *every* step — a finished request's slot is
released immediately, its pages go back to the free list, and the next
queued request is admitted into the recycled slot with its pages zeroed
in-kernel (``PagePool.alloc``). Prefill is chunked into the decode loop: an
admitted request advances one ``prefill_chunk`` of its prompt per step while
other slots keep decoding, so a long prompt never stalls the batch.

Exactly two compiled graphs run everything, regardless of admission order:

* the chunk graph  — ``paged_step`` at (n_slots, prefill_chunk); slots not
  prefilling ride along with ``n_valid = 0``;
* the decode graph — ``paged_step`` at (n_slots, 1) over every slot, active
  or not (``n_valid`` masks the rest).

Shapes never depend on which requests are in flight — per-request variation
lives entirely in the block tables, lengths and validity masks, which are
data. Page allocations are bucketed to powers of two so recycled claims fit
each other's freed runs.

Token-for-token equivalence with the wave baseline (greedy argmax over the
same model) is a test invariant, not an aspiration: ``tests/test_serving.py``
asserts it under randomized admission/finish orders.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass
from typing import Optional

import jax
import numpy as np

from repro.runtime.server import Request, ServerStats
from repro.runtime.serving.paged_cache import PagePool


@dataclass
class _Slot:
    req: Request
    pos: int = 0                    # prompt tokens prefilled so far
    pending: Optional[int] = None   # next decode input (set at prefill end)


def _bucket_pages(tokens_needed: int, page_size: int, cap: int) -> int:
    """Pages for ``tokens_needed``, rounded up to a power of two (so freed
    allocations are exchangeable between differently-sized requests)."""
    need = -(-tokens_needed // page_size)
    b = 1
    while b < need:
        b *= 2
    return min(b, cap)


class ContinuousServer:
    """Same submit/run surface as ``WaveServer``; continuous batching over
    a paged, slot-recycled KV cache."""

    def __init__(self, model, params, *, max_batch: int = 8,
                 max_len: int = 512, page_size: int = 16,
                 prefill_chunk: int = 16, n_pages: Optional[int] = None,
                 trace_logits: bool = False,
                 max_slots_per_tenant: Optional[int] = None):
        self.model = model
        self.params = params
        self.n_slots = max_batch
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        per_slot = -(-max_len // page_size)
        self.pool = PagePool(model, n_slots=max_batch,
                             n_pages=n_pages or max_batch * per_slot,
                             page_size=page_size, pages_per_slot=per_slot)
        self.slots: list[Optional[_Slot]] = [None] * max_batch
        # per-tenant admission cap: one tenant's burst cannot monopolize the
        # batch (and with it the page pool) — the confidential-serving
        # analogue of the training tier's per-silo budget isolation.
        # Requests with tenant=None are exempt (single-operator use)
        self.max_slots_per_tenant = max_slots_per_tenant
        self.queue: collections.deque[Request] = collections.deque()
        self.stats = ServerStats()
        self.clock = 0  # scheduler steps; the latency currency
        # rid -> [logits row per generated token]; the leak-freedom probe
        # asserts these are BIT-equal between a recycled-slot run and a
        # fresh-cache run
        self.trace_logits = trace_logits
        self.logit_trace: dict[int, list[np.ndarray]] = {}
        self._step_fn = jax.jit(model.paged_step, donate_argnums=(2,))

    # ------------------------------------------------------------------ queue
    def submit(self, req: Request) -> None:
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.rid} exceeds max_len {self.max_len}")
        req.submit_step = self.clock
        self.queue.append(req)

    # ------------------------------------------------------------- lifecycle
    def _tenant_slots(self, tenant: str) -> int:
        return sum(1 for s in self.slots
                   if s is not None and s.req.tenant == tenant)

    def _tenant_ok(self, req: Request) -> bool:
        return (self.max_slots_per_tenant is None or req.tenant is None
                or self._tenant_slots(req.tenant) < self.max_slots_per_tenant)

    def _admit(self) -> None:
        for i in range(self.n_slots):
            if not self.queue:
                return
            if self.slots[i] is not None:
                continue
            # first queued request whose tenant is under its slot cap: a
            # capped tenant waits, but must not head-of-line-block the other
            # tenants (admission stays FIFO *within* each tenant — the scan
            # takes the earliest admissible request)
            req = next((r for r in self.queue if self._tenant_ok(r)), None)
            if req is None:
                return
            need = _bucket_pages(len(req.prompt) + req.max_new_tokens,
                                 self.pool.page_size, self.pool.tables.shape[1])
            if not self.pool.alloc(i, need):
                return  # pool pressure: retry next step, keep FIFO order
            self.queue.remove(req)
            self.slots[i] = _Slot(req)

    def _finish(self, i: int, req: Request) -> None:
        req.done = True
        req.finish_step = self.clock
        self.stats.latencies.append(req.finish_step - req.submit_step)
        self.pool.release(i)
        self.slots[i] = None

    def _append(self, i: int, tok: int) -> bool:
        """Record a generated token; True when the request just finished."""
        req = self.slots[i].req
        req.generated.append(tok)
        self.stats.useful_tokens += 1
        if len(req.generated) >= req.max_new_tokens or \
                (req.eos_id is not None and tok == req.eos_id):
            self._finish(i, req)
            return True
        return False

    # ------------------------------------------------------------------ step
    def _run_prefill_chunks(self) -> None:
        C = self.prefill_chunk
        idx = [i for i, s in enumerate(self.slots)
               if s is not None and s.pos < len(s.req.prompt)]
        if not idx:
            return
        tokens = np.zeros((self.n_slots, C), np.int32)
        n_valid = np.zeros((self.n_slots,), np.int32)
        for i in idx:
            s = self.slots[i]
            chunk = s.req.prompt[s.pos:s.pos + C]
            tokens[i, :len(chunk)] = chunk
            n_valid[i] = len(chunk)
        logits, self.pool.pages = self._step_fn(
            self.params, tokens, self.pool.pages,
            self.pool.tables, self.pool.lengths, n_valid)
        logits = np.asarray(logits)
        for i in idx:
            s = self.slots[i]
            s.pos += int(n_valid[i])
            self.pool.lengths[i] += int(n_valid[i])
            if s.pos == len(s.req.prompt):
                # prefill done: the chunk's last-valid logits give the first
                # generated token (same source as the wave's prefill logits)
                if self.trace_logits:
                    self.logit_trace.setdefault(s.req.rid, []).append(
                        logits[i].copy())
                tok = int(np.argmax(logits[i]))
                if not self._append(i, tok):
                    s.pending = tok

    def _run_decode(self) -> None:
        idx = [i for i, s in enumerate(self.slots)
               if s is not None and s.pending is not None]
        if not idx:
            return
        tokens = np.zeros((self.n_slots, 1), np.int32)
        n_valid = np.zeros((self.n_slots,), np.int32)
        for i in idx:
            tokens[i, 0] = self.slots[i].pending
            n_valid[i] = 1
        logits, self.pool.pages = self._step_fn(
            self.params, tokens, self.pool.pages,
            self.pool.tables, self.pool.lengths, n_valid)
        logits = np.asarray(logits)
        for i in idx:
            self.pool.lengths[i] += 1
            if self.trace_logits:
                self.logit_trace.setdefault(self.slots[i].req.rid, []).append(
                    logits[i].copy())
            tok = int(np.argmax(logits[i]))
            if not self._append(i, tok):
                self.slots[i].pending = tok

    def step(self) -> None:
        """One scheduler tick: admit into free slots, decode every ready
        slot, advance every mid-prefill slot by one chunk. Decode runs
        before the chunk pass so a slot completing prefill starts decoding
        next tick — at most one token per slot per tick, which is the wave
        loop's cadence and what makes the stats comparable.

        Utilization accounting also mirrors the wave loop exactly: a tick
        that HARVESTS tokens is charged a full batch of slots (idle and
        mid-prefill slots are the measured tax); prefill compute itself is
        free, like the wave's uncharged prefill call."""
        self.clock += 1
        before = self.stats.useful_tokens
        self._admit()
        self._run_decode()
        self._run_prefill_chunks()
        if self.stats.useful_tokens > before:
            self.stats.decode_steps += 1
            self.stats.slot_tokens += self.n_slots

    def run_until_drained(self, max_steps: int = 100_000) -> ServerStats:
        while (self.queue or any(s is not None for s in self.slots)) \
                and self.clock < max_steps:
            self.step()
        return self.stats
