"""Continuous-batching scheduler over the slot-recycled paged KV cache.

Contrast with ``runtime.server.WaveServer`` (the measured baseline): instead
of draining a whole same-length wave before touching the queue, the
scheduler revisits admission at *every* step — a finished request's slot is
released immediately, its pages go back to the free list, and the next
queued request is admitted into the recycled slot with its pages zeroed
in-kernel (``PagePool.alloc``). Prefill is chunked into the decode loop: an
admitted request advances one ``prefill_chunk`` of its prompt per step while
other slots keep decoding, so a long prompt never stalls the batch.

Admission is weighted deficit-round-robin over per-tenant subqueues:
each tenant accrues credit in proportion to its weight while it waits and
spends one credit per admitted request, which converges to weighted shares
under backlog while staying strictly FIFO *within* each tenant. Subqueues
also make admission O(free slots x tenants) instead of the old O(queue^2)
scan-and-remove, and the scan stops outright once every backlogged tenant
is at its slot cap.

Two opt-in throughput layers ride on the same pool, both leak-free by
construction:

* **Prefix sharing** (``prefix_sharing=True``): an admitted request whose
  prompt starts with full pages already cached *for its own tenant* maps
  those pages read-only (refcounted, never zeroed, never written — the COW
  boundary is where its fresh pages begin) and starts prefill at the shared
  boundary. Cross-tenant sharing is structurally impossible: the tenant id
  is part of the prefix-index key (see ``paged_cache``).
* **Speculative decoding** (``speculative=True``): a draft model — the
  first ``draft_layers`` layers of the target, sharing its embedding and
  head — proposes ``spec_k - 1`` tokens per tick from a parallel draft
  pool (same page ids, same tables), and the target verifies all of them
  in ONE chunk-shaped ``paged_step`` call (``logits_mode="all"`` — the same
  function and kernels as prefill, with a full-chunk readout). Greedy
  accept keeps the emitted stream token-identical to the non-speculative
  scheduler (a test invariant, like the wave parity); the rejected tail is
  erased in-kernel (``PagePool.rollback``) from both pools before the next
  tick. The compiled-graph budget stays flat: the draft brings its own
  decode/chunk pair, verification is one extra readout variant of the
  existing chunk graph, and the target's plain decode graph is retired.

Shapes never depend on which requests are in flight — per-request variation
lives entirely in the block tables, lengths and validity masks, which are
data. Page allocations are bucketed to powers of two so recycled claims fit
each other's freed runs.

Token-for-token equivalence with the wave baseline (greedy argmax over the
same model) is a test invariant, not an aspiration: ``tests/test_serving.py``
asserts it under randomized admission/finish orders, and asserts the
speculative scheduler emits the identical stream.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import warnings
from dataclasses import dataclass
from typing import Optional

import jax
import numpy as np

from repro.runtime.server import Request, ServerStats
from repro.runtime.serving.paged_cache import PagePool


@dataclass
class _Slot:
    req: Request
    pos: int = 0                    # prompt tokens prefilled so far
    pending: Optional[int] = None   # next decode input (set at prefill end)
    seq: int = 0                    # original submit order (for re-queue)
    # effective prompt: the submitted prompt plus any tokens generated
    # before a preemption — restoring an evicted request is just prefilling
    # this (greedy decode makes the recompute token-identical, and the
    # chunked prefill's final argmax IS the next token, so nothing is
    # double-counted)
    prompt: Optional[np.ndarray] = None


def _bucket_pages(tokens_needed: int, page_size: int, cap: int) -> int:
    """Pages for ``tokens_needed``, rounded up to a power of two (so freed
    allocations are exchangeable between differently-sized requests)."""
    need = -(-tokens_needed // page_size)
    b = 1
    while b < need:
        b *= 2
    return min(b, cap)


def _draft_of(model, params, draft_layers: Optional[int]):
    """Build the draft (model, params) pair: the first ``draft_layers``
    layers of the target with the target's own embedding/final-norm/head
    (an early-exit draft — no second set of weights to train or ship).
    ``draft_layers=None`` or ``== n_layers`` is the self-draft degenerate
    case: the draft IS the target, acceptance is ~1, and the win comes
    purely from amortizing per-tick scheduler overhead over k tokens."""
    from repro.models.registry import build_model
    cfg = model.cfg
    Ld = cfg.n_layers if draft_layers is None else int(draft_layers)
    if not 1 <= Ld <= cfg.n_layers:
        raise ValueError(f"draft_layers={draft_layers} out of range for a "
                         f"{cfg.n_layers}-layer target")
    draft_model = build_model(dataclasses.replace(cfg, n_layers=Ld),
                              compute_dtype=model.compute_dtype)
    if Ld == cfg.n_layers:
        return draft_model, params
    draft_params = dict(params)
    draft_params["layers"] = jax.tree_util.tree_map(
        lambda x: x[:Ld], params["layers"])
    return draft_model, draft_params


class ContinuousServer:
    """Same submit/run surface as ``WaveServer``; continuous batching over
    a paged, slot-recycled KV cache, with optional same-tenant prefix
    sharing and speculative decoding."""

    def __init__(self, model, params, *, max_batch: int = 8,
                 max_len: int = 512, page_size: int = 16,
                 prefill_chunk: int = 16, n_pages: Optional[int] = None,
                 trace_logits: bool = False,
                 max_slots_per_tenant: Optional[int] = None,
                 tenant_weights: Optional[dict] = None,
                 prefix_sharing: bool = False,
                 speculative: bool = False, spec_k: int = 4,
                 draft_layers: Optional[int] = None):
        self.model = model
        self.params = params
        self.n_slots = max_batch
        self.max_len = max_len
        self.prefill_chunk = prefill_chunk
        self.prefix_sharing = prefix_sharing
        self.speculative = speculative
        self.spec_k = spec_k
        draft_model = self.draft_params = None
        if speculative:
            if spec_k < 2:
                raise ValueError("spec_k must be >= 2 (k=1 is plain decode)")
            draft_model, self.draft_params = _draft_of(model, params,
                                                       draft_layers)
        per_slot = -(-max_len // page_size)
        self.pool = PagePool(model, n_slots=max_batch,
                             n_pages=n_pages or max_batch * per_slot,
                             page_size=page_size, pages_per_slot=per_slot,
                             draft_model=draft_model,
                             prefix_index=prefix_sharing)
        self.slots: list[Optional[_Slot]] = [None] * max_batch
        # per-tenant admission cap: one tenant's burst cannot monopolize the
        # batch (and with it the page pool) — the confidential-serving
        # analogue of the training tier's per-silo budget isolation.
        # Requests with tenant=None are exempt (single-operator use)
        self.max_slots_per_tenant = max_slots_per_tenant
        # per-tenant FIFO subqueues of (submit seq, request) + DRR credit
        self.tenant_weights = dict(tenant_weights or {})
        self.queues: dict[Optional[str], collections.deque] = {}
        self._deficit: dict[Optional[str], float] = {}
        self._seq = 0
        self.queued = 0
        self.stats = ServerStats()
        self.clock = 0  # scheduler steps; the latency currency
        # rid -> [logits row per generated token]; the leak-freedom probe
        # asserts these are BIT-equal between a recycled-slot run and a
        # fresh-cache run
        self.trace_logits = trace_logits
        self.logit_trace: dict[int, list[np.ndarray]] = {}
        self._step_fn = jax.jit(model.paged_step, donate_argnums=(2,))
        if speculative:
            self._draft_fn = jax.jit(draft_model.paged_step,
                                     donate_argnums=(2,))
            self._verify_fn = jax.jit(
                functools.partial(model.paged_step, logits_mode="all"),
                donate_argnums=(2,))

            def _propose(dp, pool, tables, base, t0, keff):
                """All spec_k - 1 draft proposals in ONE device call: a scan
                of the draft's decode step, greedy argmax feeding the next
                step on-device. k - 1 separate dispatches would pay the
                host-sync tax speculation exists to amortize."""
                import jax.numpy as jnp

                def body(carry, j):
                    pool, cur = carry
                    nv = jnp.where(j < keff - 1, 1, 0).astype(jnp.int32)
                    logits, pool = draft_model.paged_step(
                        dp, cur[:, None], pool, tables, base + j, nv)
                    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
                    return (pool, nxt), nxt

                (pool, _), props = jax.lax.scan(
                    body, (pool, t0), jnp.arange(spec_k - 1))
                return jnp.transpose(props), pool

            self._propose_fn = jax.jit(_propose, donate_argnums=(1,))

    # ------------------------------------------------------------------ queue
    def submit(self, req: Request) -> None:
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.rid} exceeds max_len {self.max_len}")
        req.submit_step = self.clock
        self.queues.setdefault(req.tenant, collections.deque()).append(
            (self._seq, req))
        self._seq += 1
        self.queued += 1

    # ------------------------------------------------------------- lifecycle
    def _tenant_slots(self, tenant: str) -> int:
        return sum(1 for s in self.slots
                   if s is not None and s.req.tenant == tenant)

    def _tenant_ok(self, tenant: Optional[str]) -> bool:
        return (self.max_slots_per_tenant is None or tenant is None
                or self._tenant_slots(tenant) < self.max_slots_per_tenant)

    def _weight(self, tenant: Optional[str]) -> float:
        return float(self.tenant_weights.get(tenant, 1.0))

    def _admit(self) -> None:
        """Weighted deficit-round-robin over per-tenant subqueues, one pick
        per free slot. Each successful admission pays the picked tenant one
        credit and accrues weight-proportional credit to every tenant still
        waiting, so long-run admissions converge to the weight ratios while
        a capped tenant can neither head-of-line-block others (its subqueue
        is simply ineligible) nor bank unbounded credit (accrual is
        normalized: one credit total is minted per admission)."""
        for i in range(self.n_slots):
            if self.queued == 0:
                return
            if self.slots[i] is not None:
                continue
            eligible = [t for t, q in self.queues.items()
                        if q and self._tenant_ok(t)]
            if not eligible:
                return  # every backlogged tenant capped: stop scanning
            t_star = max(eligible, key=lambda t: (self._deficit.get(t, 0.0),
                                                  -self.queues[t][0][0]))
            # pop the candidate BEFORE the allocation attempt: preemption
            # may re-queue a same-tenant victim at the front of this very
            # subqueue, so a popleft afterwards could remove the wrong entry
            seq, req = self.queues[t_star].popleft()
            self.queued -= 1
            # effective prompt: original prompt + tokens generated before a
            # preemption (empty for a first admission)
            eff = req.prompt if not req.generated else np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(req.generated, np.int32)])
            shared = (self.pool.prefix_lookup(req.tenant, eff)
                      if self.prefix_sharing else [])
            need = _bucket_pages(len(req.prompt) + req.max_new_tokens,
                                 self.pool.page_size,
                                 self.pool.tables.shape[1])
            if not self.pool.alloc(i, need, shared=shared):
                # pool pressure: preempt strictly-lower-priority slots
                # until the allocation fits, else put the candidate back
                # and retry next step (FIFO order kept either way)
                if not self._preempt_for(req, i, need, shared):
                    self.queues[t_star].appendleft((seq, req))
                    self.queued += 1
                    return
            backlogged = [t for t, q in self.queues.items() if q]
            if backlogged:
                W = sum(self._weight(t) for t in backlogged)
                for t in backlogged:
                    self._deficit[t] = (self._deficit.get(t, 0.0)
                                        + self._weight(t) / W)
            self._deficit[t_star] = self._deficit.get(t_star, 0.0) - 1.0
            if not self.queues[t_star]:
                del self.queues[t_star]
                self._deficit.pop(t_star, None)
            S0 = len(shared) * self.pool.page_size
            self.stats.shared_prompt_tokens += S0
            self.slots[i] = _Slot(req, pos=S0, seq=seq,
                                  prompt=np.asarray(eff, np.int32))

    def _preempt_for(self, req: Request, i: int, need: int,
                     shared: list) -> bool:
        """Evict running slots whose priority is STRICTLY below ``req``'s
        (so equal-priority traffic can never preempt itself and there are no
        preemption cycles), cheapest recompute first (fewest generated
        tokens), until the allocation for slot ``i`` fits. Evicted requests
        go back to the FRONT of their tenant's subqueue under their original
        submit seq, so DRR ordering is undisturbed and they restore by
        recompute of prompt + generated — token-identical under greedy
        decode. Returns False (nothing evicted beyond what helped) when no
        strictly-lower-priority victim remains and the allocation still
        doesn't fit."""
        while True:
            victims = [j for j, s in enumerate(self.slots)
                       if s is not None and s.req.priority < req.priority]
            if not victims:
                return False
            j = min(victims, key=lambda j: (self.slots[j].req.priority,
                                            len(self.slots[j].req.generated)))
            s = self.slots[j]
            self.pool.release(j)
            self.slots[j] = None
            self.queues.setdefault(s.req.tenant,
                                   collections.deque()).appendleft(
                (s.seq, s.req))
            self.queued += 1
            self.stats.preemptions += 1
            if self.pool.alloc(i, need, shared=shared):
                return True

    def _finish(self, i: int, req: Request) -> None:
        req.done = True
        req.finish_step = self.clock
        self.stats.latencies.append(req.finish_step - req.submit_step)
        self.pool.release(i)
        self.slots[i] = None

    def _append(self, i: int, tok: int) -> bool:
        """Record a generated token; True when the request just finished."""
        req = self.slots[i].req
        req.generated.append(tok)
        self.stats.useful_tokens += 1
        if len(req.generated) >= req.max_new_tokens or \
                (req.eos_id is not None and tok == req.eos_id):
            self._finish(i, req)
            return True
        return False

    # ------------------------------------------------------------------ step
    def _run_prefill_chunks(self) -> None:
        C = self.prefill_chunk
        idx = [i for i, s in enumerate(self.slots)
               if s is not None and s.pos < len(s.prompt)]
        if not idx:
            return
        tokens = np.zeros((self.n_slots, C), np.int32)
        n_valid = np.zeros((self.n_slots,), np.int32)
        for i in idx:
            s = self.slots[i]
            chunk = s.prompt[s.pos:s.pos + C]
            tokens[i, :len(chunk)] = chunk
            n_valid[i] = len(chunk)
        if self.speculative:
            # keep the draft cache in lockstep: same tokens into the draft
            # pool, logits discarded — this is what makes a later sharer's
            # draft cache warm over shared prefix pages too
            _, self.pool.draft_pages = self._draft_fn(
                self.draft_params, tokens, self.pool.draft_pages,
                self.pool.tables, self.pool.lengths, n_valid)
        logits, self.pool.pages = self._step_fn(
            self.params, tokens, self.pool.pages,
            self.pool.tables, self.pool.lengths, n_valid)
        logits = np.asarray(logits)
        for i in idx:
            s = self.slots[i]
            s.pos += int(n_valid[i])
            self.pool.lengths[i] += int(n_valid[i])
            if self.prefix_sharing:
                self.pool.register_prefix(i, s.req.tenant, s.prompt, s.pos)
            if s.pos == len(s.prompt):
                # prefill done: the chunk's last-valid logits give the first
                # generated token (same source as the wave's prefill logits)
                if self.trace_logits:
                    self.logit_trace.setdefault(s.req.rid, []).append(
                        logits[i].copy())
                tok = int(np.argmax(logits[i]))
                if not self._append(i, tok):
                    s.pending = tok

    def _run_decode(self) -> None:
        idx = [i for i, s in enumerate(self.slots)
               if s is not None and s.pending is not None]
        if not idx:
            return
        tokens = np.zeros((self.n_slots, 1), np.int32)
        n_valid = np.zeros((self.n_slots,), np.int32)
        for i in idx:
            tokens[i, 0] = self.slots[i].pending
            n_valid[i] = 1
        logits, self.pool.pages = self._step_fn(
            self.params, tokens, self.pool.pages,
            self.pool.tables, self.pool.lengths, n_valid)
        logits = np.asarray(logits)
        for i in idx:
            self.pool.lengths[i] += 1
            if self.trace_logits:
                self.logit_trace.setdefault(self.slots[i].req.rid, []).append(
                    logits[i].copy())
            tok = int(np.argmax(logits[i]))
            if not self._append(i, tok):
                self.slots[i].pending = tok

    def _run_spec_decode(self) -> None:
        """One speculative tick for every decode-ready slot: ``k_eff - 1``
        draft proposals, one combined chunk-shaped verify, greedy accept,
        in-kernel rollback of the rejected tail in both pools.

        ``k_eff = min(spec_k, remaining budget)`` per slot: the bucketed
        allocation covers exactly prompt + max_new tokens, so speculating
        past the budget would write K/V past the slot's page capacity."""
        k = self.spec_k
        idx = [i for i, s in enumerate(self.slots)
               if s is not None and s.pending is not None]
        if not idx:
            return
        k_eff = {i: min(k, self.slots[i].req.max_new_tokens
                        - len(self.slots[i].req.generated)) for i in idx}
        base = self.pool.lengths.copy()
        props = {i: [self.slots[i].pending] for i in idx}
        t0 = np.zeros((self.n_slots,), np.int32)
        keff_arr = np.zeros((self.n_slots,), np.int32)
        for i in idx:
            t0[i] = props[i][0]
            keff_arr[i] = k_eff[i]
        drafted, self.pool.draft_pages = self._propose_fn(
            self.draft_params, self.pool.draft_pages, self.pool.tables,
            base, t0, keff_arr)
        drafted = np.asarray(drafted)  # (n_slots, k-1); cols >= k_eff-1 junk
        for i in idx:
            props[i] += [int(t) for t in drafted[i, :k_eff[i] - 1]]
        tokens = np.zeros((self.n_slots, k), np.int32)
        n_valid = np.zeros((self.n_slots,), np.int32)
        for i in idx:
            tokens[i, :k_eff[i]] = props[i]
            n_valid[i] = k_eff[i]
        vlogits, self.pool.pages = self._verify_fn(
            self.params, tokens, self.pool.pages,
            self.pool.tables, base, n_valid)
        vlogits = np.asarray(vlogits)  # (n_slots, k, V)
        for i in idx:
            ke, p = k_eff[i], props[i]
            # row j scores the token following p[j]; g[j] is therefore the
            # ground-truth stream, exactly what sequential decode would emit
            g = [int(np.argmax(vlogits[i, j])) for j in range(ke)]
            a = 0
            while a < ke - 1 and p[a + 1] == g[a]:
                a += 1
            self.stats.spec_proposed += ke - 1
            self.stats.spec_accepted += a
            done = False
            for j in range(a + 1):
                if self.trace_logits:
                    self.logit_trace.setdefault(
                        self.slots[i].req.rid, []).append(vlogits[i, j].copy())
                if self._append(i, g[j]):
                    done = True  # _finish released the slot: no rollback —
                    break        # its fresh pages are refcount-0 and will be
                #                  zeroed by the next admission as usual
            if not done:
                final = int(base[i]) + a + 1
                self.pool.rollback(i, final, int(base[i]) + ke)
                self.pool.lengths[i] = final
                self.slots[i].pending = g[a]

    def step(self) -> None:
        """One scheduler tick: admit into free slots, decode (or
        speculatively decode) every ready slot, advance every mid-prefill
        slot by one chunk. Decode runs before the chunk pass so a slot
        completing prefill starts decoding next tick — at most one token
        per slot per tick in plain mode (the wave loop's cadence, which is
        what makes the stats comparable), up to ``spec_k`` in speculative
        mode.

        Utilization accounting also mirrors the wave loop exactly: a tick
        that HARVESTS tokens is charged a full batch of slots (idle and
        mid-prefill slots are the measured tax) — times ``spec_k`` in
        speculative mode, where every slot had k chances; prefill compute
        itself is free, like the wave's uncharged prefill call."""
        self.clock += 1
        before = self.stats.useful_tokens
        self._admit()
        if self.speculative:
            self._run_spec_decode()
        else:
            self._run_decode()
        self._run_prefill_chunks()
        if self.stats.useful_tokens > before:
            self.stats.decode_steps += 1
            self.stats.slot_tokens += self.n_slots * (
                self.spec_k if self.speculative else 1)

    def run_until_drained(self, max_steps: int = 100_000) -> ServerStats:
        while (self.queued or any(s is not None for s in self.slots)) \
                and self.clock < max_steps:
            self.step()
        leftover = self.queued + sum(s is not None for s in self.slots)
        self.stats.drained = leftover == 0
        if leftover:
            warnings.warn(
                f"run_until_drained stopped at max_steps={max_steps} with "
                f"{leftover} requests still in flight — stats cover a "
                f"truncated trace", RuntimeWarning, stacklevel=2)
        return self.stats
