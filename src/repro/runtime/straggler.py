"""Straggler mitigation (host-side).

In SPMD training a straggling host stalls the whole collective. The policy
here implements the standard production mitigations at the level the host
loop controls:

  * deadline tracking: a step exceeding ``deadline_s`` (or an EMA-based
    adaptive deadline) is flagged; repeated flags trigger escalation,
  * escalation hook: callback to the cluster layer (re-schedule the slow
    host / shrink the mesh and restore elastically from the last checkpoint
    — see checkpoint/checkpointer.py restore-to-any-mesh).

On a real deployment the escalation callback talks to the job scheduler; in
this container it records the decision (tested in tests/test_runtime.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence


@dataclass
class SiloTelemetry:
    """Per-silo step-time attribution: EMA of each silo's observed step
    time, so straggler escalations drop the *actually* slow silo instead of
    the highest-index placeholder.

    Sources, per tier:
      * wire/protocol tier — the admin times each handler's
        ``compute_update`` round-trip (real per-party wall time; see
        api.CollaborativeSession.step);
      * barrier tier — per-host step times reported by the cluster layer's
        heartbeat (each host times its own shard; in this single-process
        container the feed is :meth:`observe` called by whoever has the
        timing);
      * fused tiers — all silos share one jitted step, so real per-silo
        timing doesn't exist; a simulated-latency hook on the Trainer
        (``silo_latency_hook``) feeds projected per-silo latencies (e.g.
        from the data-loading layer) for attribution.
    """

    n_silos: int
    ema_alpha: float = 0.3  # weight of the newest observation
    _ema: dict = field(default_factory=dict)  # silo -> EMA step time

    def observe(self, silo: int, step_time_s: float) -> None:
        prev = self._ema.get(silo)
        self._ema[silo] = step_time_s if prev is None else \
            (1.0 - self.ema_alpha) * prev + self.ema_alpha * step_time_s

    def observe_all(self, step_times_s: Sequence[float]) -> None:
        for silo, t in enumerate(step_times_s):
            self.observe(silo, float(t))

    def penalize(self, silo: int, deadline_s: float,
                 factor: float = 3.0) -> None:
        """Attribution for a silo that never responded: a non-responder has
        no round-trip to observe, but leaving its EMA untouched would make a
        hung silo look *fast* to ``slowest``. Fold in a penalty observation
        of ``deadline_s * factor`` (at least) so drop decisions and spend
        reports reflect the timeout."""
        self.observe(silo, max(deadline_s * factor,
                               self._ema.get(silo, 0.0)))

    def ema(self, silo: int) -> Optional[float]:
        return self._ema.get(silo)

    def snapshot(self) -> dict:
        """All observed EMAs (silo -> seconds) — the per-silo round-trip
        view the admin folds into the signed spend report."""
        return dict(self._ema)

    def slowest(self, candidates: Sequence[int]) -> Optional[int]:
        """The slowest silo among ``candidates`` — None when no candidate
        has an observation yet (caller falls back to its placeholder)."""
        timed = [s for s in candidates if s in self._ema]
        if not timed:
            return None
        return max(timed, key=lambda s: self._ema[s])


@dataclass
class StragglerPolicy:
    deadline_s: Optional[float] = None  # None -> adaptive (EMA * factor)
    ema_factor: float = 3.0
    escalate_after: int = 3
    on_escalate: Optional[Callable[[dict], None]] = None
    _ema: Optional[float] = None
    _strikes: int = 0
    events: list = field(default_factory=list)

    @property
    def calibrated(self) -> bool:
        return self._ema is not None

    def calibrate(self, step_time_s: float) -> None:
        """Re-anchor the adaptive baseline from an authoritative measurement
        (e.g. the amortized per-step wall time over a metrics-flush window)
        without flagging. Used by the trainer's async loop, where per-step
        dispatch times are only meaningful for *detecting* stalls (dispatch
        blocks under back-pressure) but would mis-seed the EMA."""
        self._ema = step_time_s if self._ema is None \
            else 0.5 * self._ema + 0.5 * step_time_s

    def observe(self, step_time_s: float, update_baseline: bool = True) -> bool:
        """Returns True if this step was flagged as straggling.

        ``update_baseline=False`` checks against the deadline without folding
        the sample into the adaptive EMA — for callers whose samples are only
        trustworthy as stall detectors (async dispatch times collapse to ~0
        right after a queue drain and would decay the baseline; such callers
        anchor the EMA via :meth:`calibrate` instead)."""
        if self._ema is None:
            self._ema = step_time_s
        limit = self.deadline_s if self.deadline_s is not None \
            else self._ema * self.ema_factor
        flagged = step_time_s > limit
        if flagged:
            self._strikes += 1
            self.events.append({"step_time_s": step_time_s, "limit": limit,
                                "strikes": self._strikes})
            if self._strikes >= self.escalate_after:
                decision = {"action": "reschedule", "strikes": self._strikes}
                self.events.append(decision)
                if self.on_escalate:
                    self.on_escalate(decision)
                self._strikes = 0
        else:
            self._strikes = 0
            if update_baseline:
                self._ema = 0.9 * self._ema + 0.1 * step_time_s
        return flagged
