"""Straggler mitigation (host-side).

In SPMD training a straggling host stalls the whole collective. The policy
here implements the standard production mitigations at the level the host
loop controls:

  * deadline tracking: a step exceeding ``deadline_s`` (or an EMA-based
    adaptive deadline) is flagged; repeated flags trigger escalation,
  * escalation hook: callback to the cluster layer (re-schedule the slow
    host / shrink the mesh and restore elastically from the last checkpoint
    — see checkpoint/checkpointer.py restore-to-any-mesh).

On a real deployment the escalation callback talks to the job scheduler; in
this container it records the decision (tested in tests/test_runtime.py).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Optional


@dataclass
class StragglerPolicy:
    deadline_s: Optional[float] = None  # None -> adaptive (EMA * factor)
    ema_factor: float = 3.0
    escalate_after: int = 3
    on_escalate: Optional[Callable[[dict], None]] = None
    _ema: Optional[float] = None
    _strikes: int = 0
    events: list = field(default_factory=list)

    def observe(self, step_time_s: float) -> bool:
        """Returns True if this step was flagged as straggling."""
        if self._ema is None:
            self._ema = step_time_s
        limit = self.deadline_s if self.deadline_s is not None \
            else self._ema * self.ema_factor
        flagged = step_time_s > limit
        if flagged:
            self._strikes += 1
            self.events.append({"step_time_s": step_time_s, "limit": limit,
                                "strikes": self._strikes})
            if self._strikes >= self.escalate_after:
                decision = {"action": "reschedule", "strikes": self._strikes}
                self.events.append(decision)
                if self.on_escalate:
                    self.on_escalate(decision)
                self._strikes = 0
        else:
            self._strikes = 0
            self._ema = 0.9 * self._ema + 0.1 * step_time_s
        return flagged
