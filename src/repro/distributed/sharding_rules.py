"""Logical-axis sharding rules (DP / FSDP / TP / EP / SP).

Models annotate tensors with *logical* axis names; the rules map them to mesh
axes. The mapping (DESIGN.md §6):

  batch    -> ("pod", "data")     data parallel over silos
  seq      -> ("pod", "data")     sequence parallel (only used where batch=1,
                                  e.g. long-context KV caches / encoder SP)
  heads    -> "model"             tensor parallel (Megatron attention split)
  kv_heads -> "model"             (replicated automatically if indivisible)
  ff       -> "model"             tensor parallel (FFN hidden)
  vocab    -> "model"             tensor parallel (embedding / logits)
  experts  -> "model"             expert parallel
  fsdp     -> "data"              parameter/optimizer sharding (ZeRO-3 style;
                                  within-pod so layer all-gathers stay on ICI)
  (anything else) -> replicated

A constraint axis is silently dropped when the dim is not divisible by the
mesh-axis size (e.g. kv_heads=8 on model=16 -> replicate) — degrade, don't
fail. Outside a mesh context the helpers are no-ops so model code stays
mesh-agnostic (smoke tests run on 1 CPU device).
"""
from __future__ import annotations

from typing import Optional, Sequence, Union

import jax
from jax.sharding import PartitionSpec as P

from repro import compat

Axis = Union[None, str, tuple[str, ...]]

RULES: dict[str, Axis] = {
    "batch": ("pod", "data"),
    "seq": ("pod", "data"),
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "seq_tp": "model",  # Megatron sequence parallelism (residuals)
    "fsdp": "data",
    "dhead": None,
    "dmodel": None,
    "layers": None,
    None: None,
}


def _mesh():
    return compat.get_abstract_mesh()


def _present_axes(mesh, axis: Axis) -> Optional[Axis]:
    """Prune mesh axes absent from the current mesh (e.g. 'pod' on the
    single-pod mesh) or currently Manual (inside shard_map regions only the
    Auto axes may appear in sharding constraints); None if nothing remains."""
    if axis is None:
        return None
    names = (axis,) if isinstance(axis, str) else axis
    auto = compat.auto_axis_names(mesh)
    kept = tuple(a for a in names
                 if a in mesh.axis_names and mesh.shape[a] > 1 and a in auto)
    if not kept:
        return None
    return kept[0] if len(kept) == 1 else kept


def _axis_size(mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    names = (axis,) if isinstance(axis, str) else axis
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def spec_for(logical: Sequence[Optional[str]], dims: Optional[Sequence[int]] = None,
             rules: Optional[dict] = None) -> P:
    """PartitionSpec from logical names, with divisibility fallback."""
    mesh = _mesh()
    rules = rules or RULES
    out = []
    for i, name in enumerate(logical):
        axis = rules.get(name, None)
        if axis is None or mesh is None:
            out.append(None)
            continue
        axis = _present_axes(mesh, axis)
        if axis is None:
            out.append(None)
            continue
        size = _axis_size(mesh, axis)
        if size <= 1:
            out.append(None)
            continue
        if dims is not None and dims[i] % size != 0:
            out.append(None)  # degrade to replication
            continue
        out.append(axis)
    return P(*out)


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical axis names; no-op without a mesh."""
    mesh = _mesh()
    if mesh is None:
        return x
    spec = spec_for(logical, dims=x.shape)
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# ---------------------------------------------------------------------------
# Parameter specs: map a params pytree (nested dicts of arrays) to
# PartitionSpecs by key-path naming conventions.

# (suffix or key) -> logical names for the *trailing* dims of that tensor.
# Leading stacked-layer dims are always replicated.
_PARAM_RULES: list[tuple[str, tuple[Optional[str], ...]]] = [
    ("embed", ("vocab", "fsdp")),
    ("lm_head", ("fsdp", "vocab")),
    ("wq", ("fsdp", "heads")),
    ("wk", ("fsdp", "kv_heads")),
    ("wv", ("fsdp", "kv_heads")),
    ("wo", ("heads", "fsdp")),
    ("bq", ("heads",)),
    ("bk", ("kv_heads",)),
    ("bv", ("kv_heads",)),
    ("w_gate", ("fsdp", "ff")),
    ("w_up", ("fsdp", "ff")),
    ("w_down", ("ff", "fsdp")),
    ("router", ("fsdp", "experts")),
    # expert weights: EP over the model axis on dim E; the per-expert matmul
    # dims get FSDP (both EP+TP on one mesh axis would duplicate it)
    ("we_gate", ("experts", "fsdp", None)),
    ("we_up", ("experts", "fsdp", None)),
    ("we_down", ("experts", None, "fsdp")),
    # rwkv6 / mamba2
    ("w_in", ("fsdp", "ff")),
    ("w_out", ("ff", "fsdp")),
    ("in_proj", ("fsdp", "ff")),
    ("out_proj", ("ff", "fsdp")),
    ("wr", ("fsdp", "heads")),  # rwkv time-mix receptance (head-TP)
    ("wg", ("fsdp", "heads")),  # rwkv time-mix gate
    ("w_recept", ("fsdp", "ff")),  # rwkv channel-mix receptance
    ("scale", ("fsdp",)),
]


def _match(path: str) -> Optional[tuple[Optional[str], ...]]:
    last = path.rsplit("/", 1)[-1]
    for key, names in _PARAM_RULES:
        if last == key:
            return names
    return None


def params_pspecs(params) -> "jax.tree_util.PyTreeDef":
    """PartitionSpec pytree matching ``params`` (call under a mesh context)."""
    mesh = _mesh()
    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def one(path, x):
        keys = "/".join(getattr(k, "key", str(k)) for k in path)
        names = _match(keys)
        nd = x.ndim
        if names is None or mesh is None:
            return P()
        # right-align logical names to trailing dims; leading dims replicated
        logical = [None] * (nd - len(names)) + list(names)
        return spec_for(logical[:nd] if nd >= len(names) else logical[-nd:],
                        dims=x.shape)

    specs = [one(p, x) for p, x in flat]
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(params), specs)


def named_shardings(mesh, pspecs):
    from jax.sharding import NamedSharding
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda s: isinstance(s, P))
