"""Gradient compression with error feedback (distributed-optimization trick,
DESIGN.md §6): int8 quantization, residuals carried across steps so the
compression error doesn't bias the trajectory (error-feedback SGD). Composes
with the integer-ring masking option (core/masking.py) — both are fixed point.

Wire format: the reduce is expressed as an int8 all-gather + local dequant-sum
so the collective operand really is 1 byte/element (visible in the HLO
collective-bytes roofline term), at the cost of an O(n_silos) local buffer.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_feedback(tree):
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), tree)


def compress_leaf(g, ef, scale):
    """Quantize (g + ef) at a fixed scale. Returns (int8, residual)."""
    x = g.astype(jnp.float32) + ef
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, x - q.astype(jnp.float32) * scale


def reduce_compressed(grads, ef, axis_names):
    """int8-compressed reduction over mesh axes ``axis_names`` (call inside
    shard_map manual over those axes).

    Per leaf: shared scale = pmax(local absmax)/127 -> int8 quantize (+error
    feedback) -> all_gather(int8) -> local dequant + sum. Returns (aggregate
    fp32 tree, new error-feedback tree).
    """
    leaves, treedef = jax.tree.flatten(grads)
    efl = jax.tree.leaves(ef)
    agg, new_ef = [], []
    for g, e in zip(leaves, efl):
        x = g.astype(jnp.float32) + e
        local_max = jnp.max(jnp.abs(x))
        scale = jnp.maximum(jax.lax.pmax(local_max, axis_names), 1e-12) / 127.0
        q, r = compress_leaf(g, e, scale)
        gathered = jax.lax.all_gather(q, axis_names)  # (n, ...) int8 on the wire
        agg.append(jnp.sum(gathered.astype(jnp.float32), axis=0) * scale)
        new_ef.append(r)
    return (jax.tree.unflatten(treedef, agg),
            jax.tree.unflatten(treedef, new_ef))
