"""Train / serve step builders: CITADEL++'s collaborative-training protocol
mapped onto the TPU mesh (DESIGN.md §2).

The clip+mask+noise math lives in ONE engine —
:class:`repro.core.dp_pipeline.DPPipeline` — and the step builders here are
mesh-placement shims around its stages:

``sync_path='fused'``   — pjit end-to-end. Per-silo grads via vmap over the
    silo axis of the batch, one ``run_central`` over the stacked packed
    buffer (aggregate corrected noise post-reduce). Supports FSDP param
    sharding. Production path.
``silo_mode='scan'``    — silo-serial fused path (100B-scale): a lax.scan
    accumulates clipped silo grads into an fsdp-sharded fp32 buffer; the
    engine's ``corrected_noise_tree`` stage runs on the accumulator.
``sync_path='barrier'`` — paper-faithful wire protocol: jax.shard_map manual
    over the silo axes (pod, data), model/TP axis left auto. Each silo emits
    the engine's ``silo_contribution`` (clip + zero-sum DP-mask + its noise
    share) and the explicit psum is the aggregation the model updater sees.

All paths produce the same aggregate: sum_i clip(g_i) + sigma*C*(xi_t -
lambda*xi_{t-1}), then update = aggregate / n_contributions via the
optimizer. Every step takes an ``active: (n_silos,) bool`` participation set
(elastic silo membership — see runtime/elastic.py); ``None`` means all silos
contribute.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import MeshConfig, PrivacyConfig, RunConfig
from repro.core import barrier as barrier_mod
from repro.core import dp_pipeline, flatbuf
from repro.core.dp_pipeline import DPPipeline
from repro.core.noise_correction import NoiseState, init_state as init_noise_state
from repro.distributed.sharding_rules import (constrain as constrain_logical,
                                               params_pspecs, spec_for)
from repro.models.registry import Model
from repro.optim.optimizers import Optimizer, make_optimizer
from repro.optim.schedules import constant, warmup_cosine


def constrain_tree(x, logical):
    return constrain_logical(x, *logical)


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    noise_state: NoiseState
    step: jax.Array
    clip_bound: jax.Array  # current C_t (dynamic clipping carries it)


def effective_n_silos(run_cfg: RunConfig) -> int:
    """The silo count a step function will aggregate over. The barrier tier
    is pinned to the mesh's silo-axis extent (one silo per (pod, data) mesh
    slot — the shard_map psum runs over exactly those, so the participation
    set, noise streams and divisor must all use the same count regardless of
    ``priv.n_silos``); elsewhere an explicit ``priv.n_silos`` wins, the scan
    path defaults to the paper's 4 data owners, and the mesh extent is the
    fallback."""
    priv = run_cfg.privacy
    if priv.sync_path == "barrier" and priv.enabled:
        return run_cfg.mesh.n_silos
    if priv.n_silos:
        return priv.n_silos
    if priv.silo_mode == "scan":
        return 4  # the paper's evaluation deploys 4 data-handling silos
    return run_cfg.mesh.n_silos


def init_train_state(model: Model, run_cfg: RunConfig, key) -> TrainState:
    params = model.init(key)
    opt = make_optimizer(run_cfg.optimizer)
    return TrainState(
        params=params,
        opt_state=opt.init(params),
        noise_state=init_noise_state(jax.random.fold_in(key, 0xD0),
                                     n_silos=effective_n_silos(run_cfg)),
        step=jnp.zeros((), jnp.int32),
        clip_bound=jnp.asarray(run_cfg.privacy.clip_bound, jnp.float32),
    )


def _reshape_to_silos(batch: dict, n_silos: int) -> dict:
    out = {}
    for k, v in batch.items():
        if k == "positions" and v.ndim == 3:  # M-RoPE ids (3, B, S)
            out[k] = v.reshape((3, n_silos, v.shape[1] // n_silos) + v.shape[2:]) \
                      .transpose(1, 0, 2, 3)
        else:
            out[k] = v.reshape((n_silos, v.shape[0] // n_silos) + v.shape[1:])
    return out


# ---------------------------------------------------------------------------
# Fused path


def _active_or_full(active, pipe: DPPipeline):
    return pipe.full_active() if active is None else \
        jnp.asarray(active, jnp.bool_)


def _fused_grads(model: Model, priv: PrivacyConfig, params, batch, n_silos,
                 keys, noise_state, clip_bound, clip_key, active=None):
    """vmap shim: per-silo grads stacked as ONE (n_silos, P) packed buffer
    (each silo's pytree is packed inside the vmap), then the engine's
    ``run_central`` does the rest — norms -> dynamic_bound -> clip_scale ->
    masked_aggregate -> corrected_noise — over the participation set."""
    silo_batches = _reshape_to_silos(batch, n_silos)
    layout = flatbuf.layout_of(params)  # grads share the params treedef
    pipe = DPPipeline(priv, layout, n_silos)
    active = _active_or_full(active, pipe)

    def per_silo(b):
        loss, g = jax.value_and_grad(model.loss)(params, b)
        flat = flatbuf.pack(layout, g)
        # norm off the packed buffer (padding is exactly zero): one reduce
        # instead of a per-leaf sumsq chain
        return loss, flat, jnp.sqrt(jnp.sum(flat * flat))

    losses, g_packed, norms = jax.vmap(per_silo)(silo_batches)  # (n_silos, P)

    if priv.enabled and pipe.policy.mode == "perleaf":
        # legacy per-leaf noise family (force_impl / REPRO_KERNEL_IMPL):
        # aggregate packed, then the tree-level noise stage
        bound = pipe.dynamic_bound(norms, active, clip_key, clip_bound)
        g_sum = pipe.masked_aggregate(g_packed,
                                      pipe.clip_scales(norms, bound, active))
        g_tree = flatbuf.unpack(layout, g_sum, dtype=jnp.float32)
        noisy = pipe.corrected_noise_tree(g_tree, keys, noise_state, bound,
                                          active)
        new_ns = pipe.advance_state(keys, noise_state, active)
    else:
        noisy, new_ns, bound = pipe.run_central(
            g_packed, norms, keys, noise_state, clip_bound, clip_key, active)
    gates = active.astype(losses.dtype)
    loss = jnp.sum(losses * gates) / pipe.active_count(active)
    return noisy, loss, norms, new_ns, bound


def _fused_grads_scan(model: Model, priv: PrivacyConfig, params, batch,
                      n_silos, keys, noise_state, clip_bound, clip_key,
                      active=None):
    """scan shim (100B-scale): silos are processed sequentially; each silo's
    gradient is data-parallel over the whole mesh (FSDP reduce-scatter keeps
    the transient at P/n_devices), weighted by the engine's clip scale for
    the carried bound C_t (dynamic clipping is stale-by-one — the standard
    production DP-SGD quantile scheme) and its participation gate, and
    accumulated into a single fsdp-sharded fp32 buffer. The engine's
    ``corrected_noise_tree`` stage runs on the accumulator — per-leaf policy
    by default, which keeps the FSDP sharding (the packed engine would
    gather the full parameter buffer onto every device;
    ``REPRO_KERNEL_IMPL=dp_noise_tree=packed`` overrides if wanted)."""
    silo_batches = _reshape_to_silos(batch, n_silos)
    # inner batch dim stays sharded over the silo axes (the scan consumes dim0)
    silo_batches = {
        k: (constrain_tree(v, (None, None, "batch", None)) if k == "positions"
            else constrain_tree(v, (None, "batch") + (None,) * (v.ndim - 2)))
        for k, v in silo_batches.items()}

    pipe = DPPipeline(priv, flatbuf.layout_of(params), n_silos,
                      policy="perleaf")
    active = _active_or_full(active, pipe)
    param_pspecs = params_pspecs(params)

    def constrain_acc(t):
        def one(x, s):
            if all(e is None for e in s):
                return x
            return jax.lax.with_sharding_constraint(x, s)
        return jax.tree.map(one, t, param_pspecs,
                            is_leaf=lambda n: hasattr(n, "shape"))

    acc0 = constrain_acc(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def body(carry, xs):
        acc, loss_acc = carry
        b, gate = xs
        loss, g = jax.value_and_grad(model.loss)(params, b)
        norm = pipe.norm_tree(g)
        scale = pipe.clip_scale(norm, clip_bound) \
            if priv.enabled else jnp.asarray(1.0, jnp.float32)
        scale = scale * gate
        acc = constrain_acc(jax.tree.map(
            lambda a, gg: a + scale * gg.astype(jnp.float32), acc, g))
        return (acc, loss_acc + loss * gate), norm

    gates = active.astype(jnp.float32)
    (g_sum, loss_sum), norms = jax.lax.scan(
        body, (acc0, jnp.zeros((), jnp.float32)), (silo_batches, gates))

    new_bound = pipe.dynamic_bound(norms, active, clip_key, clip_bound)

    if priv.enabled:
        noisy = pipe.corrected_noise_tree(g_sum, keys, noise_state,
                                          clip_bound, active)
        new_ns = pipe.advance_state(keys, noise_state, active)
    else:
        noisy, new_ns = g_sum, noise_state
    return noisy, loss_sum / pipe.active_count(active), norms, new_ns, new_bound


# ---------------------------------------------------------------------------
# Barrier path (paper-faithful)


def _barrier_grads(model: Model, priv: PrivacyConfig, mesh_cfg: MeshConfig,
                   params, batch, keys, noise_state, clip_bound, clip_key,
                   abstract_mesh, active=None):
    """shard_map shim: each silo emits the engine's ``silo_contribution``
    (clip + zero-sum mask over the active ring + its noise share, one fused
    dispatch on the packed buffer) and the explicit psum over the silo axes
    — one collective on the packed buffer — is the aggregation the model
    updater sees. The masked per-silo gradients exist on the wire exactly as
    in the paper."""
    n_silos = mesh_cfg.n_silos
    silo_axes = mesh_cfg.silo_axes
    pipe = DPPipeline(priv, flatbuf.layout_of(params), n_silos)
    if pipe.policy.mode == "perleaf":
        # the per-leaf mask family only supports the full static ring
        active = None
    active_arr = _active_or_full(active, pipe)
    has_prev_active = noise_state.prev_active is not None
    prev_active_arr = noise_state.prev_active if has_prev_active \
        else pipe.full_active()

    def silo_fn(params, batch_local, key_r, key_xi, prev_key, has_prev,
                prev_active, clip_bound, clip_key, active):
        idx = jnp.zeros((), jnp.int32)
        mult = 1
        for ax in reversed(silo_axes):
            idx = idx + jax.lax.axis_index(ax) * mult
            mult *= compat.axis_size(ax)
        loss, g = jax.value_and_grad(model.loss)(params, batch_local)
        norm = pipe.norm_tree(g)

        if priv.dynamic_clip:
            all_norms = jax.lax.all_gather(norm[None], silo_axes)  # (n_silos, 1)
            clip_bound = pipe.dynamic_bound(all_norms.reshape(-1), active,
                                            clip_key, clip_bound)

        # clip folds into the fused packed clip+mask+noise dispatch
        scale = pipe.clip_scale(norm, clip_bound)
        keys_t = barrier_mod.BarrierKeys(key_r, key_xi, clip_key)
        ns = NoiseState(prev_key=prev_key, has_prev=has_prev,
                        prev_active=prev_active if has_prev_active else None)
        contrib = pipe.silo_contribution(g, idx, scale, active, keys_t, ns,
                                         clip_bound)
        agg = pipe.finalize(jax.lax.psum(contrib, silo_axes))
        gate = active[idx].astype(jnp.float32)
        loss_mean = jax.lax.psum(loss * gate, silo_axes) / \
            pipe.active_count(active)
        return agg, loss_mean, norm[None], clip_bound

    batch_spec = {k: (P(None, silo_axes) if k == "positions" and v.ndim == 3
                      else P(silo_axes))
                  for k, v in batch.items()}

    fn = compat.shard_map(
        silo_fn,
        mesh=abstract_mesh,
        in_specs=(P(), batch_spec, P(), P(), P(), P(), P(), P(), P(), P()),
        out_specs=(P(), P(), P(silo_axes), P()),
        axis_names=set(silo_axes),
        check_vma=False,
    )
    agg, loss, norms, new_bound = fn(
        params, batch, keys.key_r, keys.key_xi, noise_state.prev_key,
        noise_state.has_prev, prev_active_arr, clip_bound, keys.key_clip,
        active_arr)
    new_ns = pipe.advance_state(keys, noise_state, active_arr) \
        if priv.enabled else noise_state
    return agg, loss, norms, new_ns, new_bound


# ---------------------------------------------------------------------------
# Step builders


def build_train_step(model: Model, run_cfg: RunConfig, abstract_mesh=None,
                     lr_schedule=None, elastic: bool = False):
    """The jitted CITADEL++ train step. ``train_step(state, batch, root_key,
    active=None)``: ``active`` is the (n_silos,) bool participation set for
    this step — dropped silos contribute neither gradient, mask, noise share
    nor divisor weight. ``elastic=True`` only validates up front that the
    configured tier can honour a partial set (the barrier tier needs the
    packed mask family for the active-ring construction)."""
    priv = run_cfg.privacy
    mesh_cfg = run_cfg.mesh
    opt = make_optimizer(run_cfg.optimizer)
    lr_schedule = lr_schedule or constant(run_cfg.optimizer.lr)
    n_silos = effective_n_silos(run_cfg)

    if elastic and priv.enabled and priv.sync_path == "barrier":
        policy = dp_pipeline.resolve_policy("packed", 1)
        if policy.mode == "perleaf":
            raise ValueError(
                "elastic membership on the barrier tier needs the packed "
                "mask engine (the per-leaf family only builds the full "
                "static ring); lift the dp_noise_tree=perleaf override")

    def train_step(state: TrainState, batch, root_key, active=None):
        keys = barrier_mod.step_keys(root_key, state.step)
        if active is None:
            active = jnp.ones((n_silos,), jnp.bool_)
        if active.shape != (n_silos,):
            raise ValueError(
                f"participation set has shape {active.shape}, but this step "
                f"aggregates over {n_silos} silos"
                + (" (the barrier tier pins the count to the mesh's silo-"
                   "axis extent, not priv.n_silos)"
                   if priv.sync_path == "barrier" and priv.enabled else ""))
        if priv.sync_path == "barrier" and priv.enabled:
            noisy, loss, norms, new_ns, bound = _barrier_grads(
                model, priv, mesh_cfg, state.params, batch, keys,
                state.noise_state, state.clip_bound, keys.key_clip,
                abstract_mesh, active=active)
        elif priv.silo_mode == "scan":
            noisy, loss, norms, new_ns, bound = _fused_grads_scan(
                model, priv, state.params, batch, n_silos, keys,
                state.noise_state, state.clip_bound, keys.key_clip,
                active=active)
        else:
            noisy, loss, norms, new_ns, bound = _fused_grads(
                model, priv, state.params, batch, n_silos, keys,
                state.noise_state, state.clip_bound, keys.key_clip,
                active=active)

        # the aggregate is divided by the silos that actually contributed
        n_contrib = jnp.maximum(jnp.sum(active.astype(jnp.float32)), 1.0)
        grad = jax.tree.map(lambda g: g / n_contrib, noisy)
        lr = lr_schedule(state.step)
        new_params, new_opt = opt.update(state.params, state.opt_state, grad, lr)
        gates = active.astype(jnp.float32)
        norm_mean = jnp.sum(norms.reshape(-1)[:n_silos] * gates) / n_contrib \
            if norms.shape[0] == n_silos else jnp.mean(norms)
        metrics = {"loss": loss, "grad_norm_mean": norm_mean,
                   "clip_bound": bound, "lr": lr,
                   "n_contributions": n_contrib}
        return TrainState(new_params, new_opt, new_ns, state.step + 1, bound), metrics

    return train_step


def build_serve_step(model: Model, kind: str = "decode"):
    if kind == "prefill":
        def prefill_step(params, batch, cache):
            return model.prefill(params, batch, cache)
        return prefill_step

    def decode_step(params, batch, cache):
        return model.decode_step(params, batch, cache)
    return decode_step


# ---------------------------------------------------------------------------
# Sharding helpers for jit


def state_pspecs(state: TrainState):
    """PartitionSpecs for a TrainState under the current mesh context."""
    p_specs = params_pspecs(state.params)
    # opt entries mirror params: master/m/v share the params' sharding
    def opt_spec(d):
        out = {}
        for k, v in d.items():
            if k in ("master", "m", "v", "mu"):
                out[k] = p_specs
            else:
                out[k] = jax.tree.map(lambda _: P(), v)
        return out
    return TrainState(
        params=p_specs,
        opt_state=opt_spec(state.opt_state),
        noise_state=jax.tree.map(lambda _: P(), state.noise_state),
        step=P(),
        clip_bound=P(),
    )


def batch_pspec(batch, silo_axes=("pod", "data")):
    """Shard the batch dim over the silo axes where divisible; batch=1 shapes
    (long-context decode) fall back to sequence sharding / replication."""
    mesh = compat.get_abstract_mesh()
    n = 1
    axes = tuple(a for a in silo_axes
                 if mesh is not None and a in (mesh.axis_names or ()))
    for a in axes:
        n *= mesh.shape[a]
    axes = axes or silo_axes

    def one(k, v):
        if k == "positions" and v.ndim == 3:
            if v.shape[1] % max(n, 1) == 0 and v.shape[1] > 1:
                return P(None, axes)
            return P()
        if v.shape[0] % max(n, 1) == 0 and v.shape[0] > 1:
            return P(axes)
        if v.ndim > 1 and v.shape[1] % max(n, 1) == 0 and v.shape[1] > 1:
            return P(None, axes)  # sequence-sharded fallback
        return P()

    return {k: one(k, v) for k, v in batch.items()}
