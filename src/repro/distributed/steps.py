"""Train / serve step builders: CITADEL++'s collaborative-training protocol
mapped onto the TPU mesh (DESIGN.md §2).

``sync_path='fused'``   — pjit end-to-end. Per-silo clipping via vmap over the
    silo axis of the batch, aggregate corrected DP noise injected post-reduce.
    Supports FSDP param sharding. Production path.

``sync_path='barrier'`` — paper-faithful wire protocol: jax.shard_map manual
    over the silo axes (pod, data), model/TP axis left auto. Each silo
    computes its gradient, clips, applies its zero-sum DP-mask, and the
    explicit psum is the aggregation the model updater sees. Params are
    replicated across silos (the paper's FL memory model: every data-handling
    component holds the full model replica).

Both paths produce the same aggregate: sum_i clip(g_i) + sigma*C*(xi_t -
lambda*xi_{t-1}), then update = aggregate / n_contributions via the optimizer.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.configs.base import MeshConfig, PrivacyConfig, RunConfig
from repro.core import barrier as barrier_mod
from repro.core import clipping, flatbuf
from repro.core.noise_correction import NoiseState, init_state as init_noise_state
from repro.kernels.dispatch import REGISTRY
from repro.kernels.dp_clip import ops as clip_ops
from repro.distributed.sharding_rules import (constrain as constrain_logical,
                                               params_pspecs, spec_for)


def constrain_tree(x, logical):
    return constrain_logical(x, *logical)
from repro.models.registry import Model
from repro.optim.optimizers import Optimizer, make_optimizer
from repro.optim.schedules import constant, warmup_cosine


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    noise_state: NoiseState
    step: jax.Array
    clip_bound: jax.Array  # current C_t (dynamic clipping carries it)


def init_train_state(model: Model, run_cfg: RunConfig, key) -> TrainState:
    params = model.init(key)
    opt = make_optimizer(run_cfg.optimizer)
    return TrainState(
        params=params,
        opt_state=opt.init(params),
        noise_state=init_noise_state(jax.random.fold_in(key, 0xD0)),
        step=jnp.zeros((), jnp.int32),
        clip_bound=jnp.asarray(run_cfg.privacy.clip_bound, jnp.float32),
    )


def _reshape_to_silos(batch: dict, n_silos: int) -> dict:
    out = {}
    for k, v in batch.items():
        if k == "positions" and v.ndim == 3:  # M-RoPE ids (3, B, S)
            out[k] = v.reshape((3, n_silos, v.shape[1] // n_silos) + v.shape[2:]) \
                      .transpose(1, 0, 2, 3)
        else:
            out[k] = v.reshape((n_silos, v.shape[0] // n_silos) + v.shape[1:])
    return out


# ---------------------------------------------------------------------------
# Fused path


def _fused_grads(model: Model, priv: PrivacyConfig, params, batch, n_silos,
                 keys, noise_state, clip_bound, clip_key):
    """Per-silo clipped grads via vmap; aggregate noise post-reduce.

    The whole post-grad pipeline runs on ONE packed flat buffer
    (core/flatbuf): each silo's gradient pytree is packed inside the vmap —
    the per-silo gradient stack is a single (n_silos, P) buffer instead of a
    pytree of stacks — the scale-and-sum folds into one packed accumulate
    kernel, the corrected DP noise is one fused dispatch on the (P,) sum,
    and the tree is unpacked exactly once at the end."""
    silo_batches = _reshape_to_silos(batch, n_silos)
    layout = flatbuf.layout_of(params)  # grads share the params treedef

    def per_silo(b):
        loss, g = jax.value_and_grad(model.loss)(params, b)
        flat = flatbuf.pack(layout, g)
        # norm off the packed buffer (padding is exactly zero): one reduce
        # instead of a per-leaf sumsq chain
        return loss, flat, jnp.sqrt(jnp.sum(flat * flat))

    losses, g_packed, norms = jax.vmap(per_silo)(silo_batches)  # (n_silos, P)

    if priv.enabled and priv.dynamic_clip:
        pcts = clipping.local_percentiles(norms)  # global view under pjit
        clip_bound = barrier_mod.dynamic_bound_from_percentiles(
            pcts[None], priv, clip_key)

    if priv.enabled:
        scale = clipping.clip_scale(norms, clip_bound)
    else:
        scale = jnp.ones_like(norms)
    g_sum = clip_ops.clipped_sum(g_packed, scale)  # (P,) fp32, one dispatch

    if priv.enabled:
        # default packed, but honour force_impl / REPRO_KERNEL_IMPL on
        # dp_noise_tree (an explicit perleaf/jnp override falls back to the
        # legacy per-leaf jax.random noise on the unpacked tree)
        variant = REGISTRY.resolve("dp_noise_tree", "packed",
                                   {"n_leaves": layout.n_leaves}).name
        if variant in ("perleaf", "jnp"):
            g_tree = flatbuf.unpack(layout, g_sum, dtype=jnp.float32)
            noisy, new_ns = barrier_mod.fused_noise(
                g_tree, priv, keys, noise_state, clip_bound, impl=variant)
            return noisy, jnp.mean(losses), norms, new_ns, clip_bound
        noisy_packed, new_ns = barrier_mod.fused_noise_packed(
            g_sum, priv, keys, noise_state, clip_bound,
            impl="pallas" if variant == "pallas" else "auto")
    else:
        noisy_packed, new_ns = g_sum, noise_state
    noisy = flatbuf.unpack(layout, noisy_packed, dtype=jnp.float32)
    return noisy, jnp.mean(losses), norms, new_ns, clip_bound


def _fused_grads_scan(model: Model, priv: PrivacyConfig, params, batch,
                      n_silos, keys, noise_state, clip_bound, clip_key):
    """Silo-serial fused path (100B-scale): silos are processed sequentially;
    each silo's gradient is data-parallel over the whole mesh (FSDP
    reduce-scatter keeps the transient at P/n_devices), clipped with the
    carried bound C_{t} (derived from step t-1 norms), and accumulated into a
    single fsdp-sharded fp32 buffer. Dynamic clipping is stale-by-one —
    the standard production DP-SGD quantile scheme."""
    silo_batches = _reshape_to_silos(batch, n_silos)
    # inner batch dim stays sharded over the silo axes (the scan consumes dim0)
    silo_batches = {
        k: (constrain_tree(v, (None, None, "batch", None)) if k == "positions"
            else constrain_tree(v, (None, "batch") + (None,) * (v.ndim - 2)))
        for k, v in silo_batches.items()}

    param_pspecs = params_pspecs(params)

    def constrain_acc(t):
        def one(x, s):
            if all(e is None for e in s):
                return x
            return jax.lax.with_sharding_constraint(x, s)
        return jax.tree.map(one, t, param_pspecs,
                            is_leaf=lambda n: hasattr(n, "shape"))

    acc0 = constrain_acc(jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))

    def body(carry, b):
        acc, loss_acc = carry
        loss, g = jax.value_and_grad(model.loss)(params, b)
        norm = clipping.global_norm(g)
        scale = clipping.clip_scale(norm, clip_bound) \
            if priv.enabled else jnp.asarray(1.0, jnp.float32)
        acc = constrain_acc(jax.tree.map(
            lambda a, gg: a + scale * gg.astype(jnp.float32), acc, g))
        return (acc, loss_acc + loss), norm

    (g_sum, loss_sum), norms = jax.lax.scan(body, (acc0, jnp.zeros((), jnp.float32)),
                                            silo_batches)

    if priv.enabled and priv.dynamic_clip:
        pcts = clipping.local_percentiles(norms)
        new_bound = barrier_mod.dynamic_bound_from_percentiles(
            pcts[None], priv, clip_key)
    else:
        new_bound = clip_bound

    if priv.enabled:
        # perleaf on purpose: the accumulator is fsdp-sharded and the packed
        # engine would gather the full parameter buffer onto every device
        # (REPRO_KERNEL_IMPL=dp_noise_tree=packed overrides if wanted)
        noisy, new_ns = barrier_mod.fused_noise(g_sum, priv, keys, noise_state,
                                                clip_bound, impl="perleaf")
    else:
        noisy, new_ns = g_sum, noise_state
    return noisy, loss_sum / n_silos, norms, new_ns, new_bound


# ---------------------------------------------------------------------------
# Barrier path (paper-faithful)


def _barrier_grads(model: Model, priv: PrivacyConfig, mesh_cfg: MeshConfig,
                   params, batch, keys, noise_state, clip_bound, clip_key,
                   abstract_mesh):
    n_silos = mesh_cfg.n_silos
    silo_axes = mesh_cfg.silo_axes

    def silo_fn(params, batch_local, key_r, key_xi, prev_key, has_prev,
                clip_bound, clip_key):
        idx = jnp.zeros((), jnp.int32)
        mult = 1
        for ax in reversed(silo_axes):
            idx = idx + jax.lax.axis_index(ax) * mult
            mult *= compat.axis_size(ax)
        loss, g = jax.value_and_grad(model.loss)(params, batch_local)
        norm = clipping.global_norm(g)

        if priv.dynamic_clip:
            pcts = clipping.local_percentiles(norm[None])
            all_pcts = jax.lax.all_gather(pcts, silo_axes)  # (n_silos, n_pct)
            clip_bound = barrier_mod.dynamic_bound_from_percentiles(
                all_pcts, priv, clip_key)

        # clip folds into the fused packed clip+mask+noise dispatch
        scale = clipping.clip_scale(norm, clip_bound)
        keys_t = barrier_mod.BarrierKeys(key_r, key_xi, clip_key)
        ns = NoiseState(prev_key=prev_key, has_prev=has_prev)
        agg, new_ns = barrier_mod.barrier_sync(
            g, idx, n_silos, priv, keys_t, ns, clip_bound,
            axis_names=silo_axes, scale=scale)
        loss_mean = jax.lax.pmean(loss, silo_axes)
        return agg, loss_mean, norm[None], new_ns.prev_key, new_ns.has_prev, clip_bound

    batch_spec = {k: (P(None, silo_axes) if k == "positions" and v.ndim == 3
                      else P(silo_axes))
                  for k, v in batch.items()}

    fn = compat.shard_map(
        silo_fn,
        mesh=abstract_mesh,
        in_specs=(P(), batch_spec, P(), P(), P(), P(), P(), P()),
        out_specs=(P(), P(), P(silo_axes), P(), P(), P()),
        axis_names=set(silo_axes),
        check_vma=False,
    )
    agg, loss, norms, prev_key, has_prev, new_bound = fn(
        params, batch, keys.key_r, keys.key_xi, noise_state.prev_key,
        noise_state.has_prev, clip_bound, keys.key_clip)
    return agg, loss, norms, NoiseState(prev_key, has_prev), new_bound


# ---------------------------------------------------------------------------
# Step builders


def build_train_step(model: Model, run_cfg: RunConfig, abstract_mesh=None,
                     lr_schedule=None):
    priv = run_cfg.privacy
    mesh_cfg = run_cfg.mesh
    opt = make_optimizer(run_cfg.optimizer)
    lr_schedule = lr_schedule or constant(run_cfg.optimizer.lr)
    n_silos = mesh_cfg.n_silos

    if priv.n_silos:
        n_silos = priv.n_silos
    elif priv.silo_mode == "scan":
        n_silos = 4  # the paper's evaluation deploys 4 data-handling silos

    def train_step(state: TrainState, batch, root_key):
        keys = barrier_mod.step_keys(root_key, state.step)
        if priv.sync_path == "barrier" and priv.enabled:
            noisy, loss, norms, new_ns, bound = _barrier_grads(
                model, priv, mesh_cfg, state.params, batch, keys,
                state.noise_state, state.clip_bound, keys.key_clip,
                abstract_mesh)
        elif priv.silo_mode == "scan":
            noisy, loss, norms, new_ns, bound = _fused_grads_scan(
                model, priv, state.params, batch, n_silos, keys,
                state.noise_state, state.clip_bound, keys.key_clip)
        else:
            noisy, loss, norms, new_ns, bound = _fused_grads(
                model, priv, state.params, batch, n_silos, keys,
                state.noise_state, state.clip_bound, keys.key_clip)

        grad = jax.tree.map(lambda g: g / n_silos, noisy)
        lr = lr_schedule(state.step)
        new_params, new_opt = opt.update(state.params, state.opt_state, grad, lr)
        metrics = {"loss": loss, "grad_norm_mean": jnp.mean(norms),
                   "clip_bound": bound, "lr": lr}
        return TrainState(new_params, new_opt, new_ns, state.step + 1, bound), metrics

    return train_step


def build_serve_step(model: Model, kind: str = "decode"):
    if kind == "prefill":
        def prefill_step(params, batch, cache):
            return model.prefill(params, batch, cache)
        return prefill_step

    def decode_step(params, batch, cache):
        return model.decode_step(params, batch, cache)
    return decode_step


# ---------------------------------------------------------------------------
# Sharding helpers for jit


def state_pspecs(state: TrainState):
    """PartitionSpecs for a TrainState under the current mesh context."""
    p_specs = params_pspecs(state.params)
    # opt entries mirror params: master/m/v share the params' sharding
    def opt_spec(d):
        out = {}
        for k, v in d.items():
            if k in ("master", "m", "v", "mu"):
                out[k] = p_specs
            else:
                out[k] = jax.tree.map(lambda _: P(), v)
        return out
    return TrainState(
        params=p_specs,
        opt_state=opt_spec(state.opt_state),
        noise_state=jax.tree.map(lambda _: P(), state.noise_state),
        step=P(),
        clip_bound=P(),
    )


def batch_pspec(batch, silo_axes=("pod", "data")):
    """Shard the batch dim over the silo axes where divisible; batch=1 shapes
    (long-context decode) fall back to sequence sharding / replication."""
    mesh = compat.get_abstract_mesh()
    n = 1
    axes = tuple(a for a in silo_axes
                 if mesh is not None and a in (mesh.axis_names or ()))
    for a in axes:
        n *= mesh.shape[a]
    axes = axes or silo_axes

    def one(k, v):
        if k == "positions" and v.ndim == 3:
            if v.shape[1] % max(n, 1) == 0 and v.shape[1] > 1:
                return P(None, axes)
            return P()
        if v.shape[0] % max(n, 1) == 0 and v.shape[0] > 1:
            return P(axes)
        if v.ndim > 1 and v.shape[1] % max(n, 1) == 0 and v.shape[1] > 1:
            return P(None, axes)  # sequence-sharded fallback
        return P()

    return {k: one(k, v) for k, v in batch.items()}
