"""Per-silo data pipeline: deterministic, restart-safe batch iteration.

Each dataset owner's data handling component iterates its own shard. The
iterator state is just (epoch, step) — checkpointable, so a restarted trainer
resumes on the exact batch it would have seen (fault tolerance requires the
DP accountant's view of data access to be reproducible).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.data.synthetic import ArrayDataset


@dataclass
class SiloIterator:
    data: ArrayDataset
    batch: int
    seed: int = 0
    step: int = 0

    def _order(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, epoch))
        return rng.permutation(len(self.data))

    def next(self) -> dict:
        per_epoch = max(len(self.data) // self.batch, 1)
        epoch, within = divmod(self.step, per_epoch)
        order = self._order(epoch)
        idx = order[(within * self.batch) % len(self.data):][: self.batch]
        if len(idx) < self.batch:  # wrap
            idx = np.concatenate([idx, order[: self.batch - len(idx)]])
        self.step += 1
        return {"x": self.data.x[idx], "y": self.data.y[idx]}

    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def load_state_dict(self, d: dict) -> None:
        self.step = d["step"]
        self.seed = d["seed"]


class FederatedBatcher:
    """Assembles the cross-silo global batch (leading dim = silos-flattened)
    matching the train step's ``_reshape_to_silos`` layout."""

    def __init__(self, silos: list[ArrayDataset], per_silo_batch: int, seed: int = 0):
        self.iters = [SiloIterator(d, per_silo_batch, seed + i)
                      for i, d in enumerate(silos)]

    def next(self) -> dict:
        parts = [it.next() for it in self.iters]
        return {k: np.concatenate([p[k] for p in parts], axis=0)
                for k in parts[0]}

    def state_dict(self) -> dict:
        return {"iters": [it.state_dict() for it in self.iters]}

    def load_state_dict(self, d: dict) -> None:
        for it, s in zip(self.iters, d["iters"]):
            it.load_state_dict(s)
