"""Synthetic datasets (offline container: no real MNIST/CIFAR download).

The classification datasets are *learnable* mixtures-of-prototypes so the
paper's utility-vs-epsilon curves are reproducible in shape: each class has a
few prototype patterns; samples are prototypes + Gaussian pixel noise. LM
data is a token stream from a mixture of Markov chains (so next-token loss is
learnable below the uniform entropy floor).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class ArrayDataset:
    x: np.ndarray
    y: np.ndarray

    def __len__(self):
        return len(self.x)

    def split(self, n_parts: int) -> list["ArrayDataset"]:
        """Partition across dataset owners (silos)."""
        xs = np.array_split(self.x, n_parts)
        ys = np.array_split(self.y, n_parts)
        return [ArrayDataset(a, b) for a, b in zip(xs, ys)]


def synthetic_images(n: int, hw: int, channels: int, n_classes: int,
                     seed: int = 0, noise: float = 0.35,
                     prototypes_per_class: int = 3) -> ArrayDataset:
    rng = np.random.default_rng(seed)
    protos = rng.normal(0.0, 1.0, (n_classes, prototypes_per_class, hw, hw, channels))
    y = rng.integers(0, n_classes, n)
    pick = rng.integers(0, prototypes_per_class, n)
    x = protos[y, pick] + rng.normal(0.0, noise, (n, hw, hw, channels))
    return ArrayDataset(x.astype(np.float32), y.astype(np.int32))


def synthetic_mnist(n_train: int = 8192, n_test: int = 2048, seed: int = 0):
    tr = synthetic_images(n_train, 28, 1, 10, seed)
    te = synthetic_images(n_test, 28, 1, 10, seed + 1)
    # same prototypes for train/test: regenerate test from train prototypes
    rng = np.random.default_rng(seed)
    protos = rng.normal(0.0, 1.0, (10, 3, 28, 28, 1))
    rng2 = np.random.default_rng(seed + 1000)
    y = rng2.integers(0, 10, n_test)
    pick = rng2.integers(0, 3, n_test)
    te = ArrayDataset((protos[y, pick] + rng2.normal(0, 0.35, (n_test, 28, 28, 1))).astype(np.float32),
                      y.astype(np.int32))
    return tr, te


def synthetic_cifar10(n_train: int = 8192, n_test: int = 2048, seed: int = 7):
    rng = np.random.default_rng(seed)
    protos = rng.normal(0.0, 1.0, (10, 3, 32, 32, 3))

    def make(n, s):
        r = np.random.default_rng(s)
        y = r.integers(0, 10, n)
        pick = r.integers(0, 3, n)
        x = protos[y, pick] + r.normal(0, 0.35, (n, 32, 32, 3))
        return ArrayDataset(x.astype(np.float32), y.astype(np.int32))

    return make(n_train, seed + 1), make(n_test, seed + 2)


def synthetic_tokens(n_seqs: int, seq_len: int, vocab: int, seed: int = 0,
                     n_chains: int = 4) -> np.ndarray:
    """Mixture of order-1 Markov chains over a small effective vocabulary."""
    rng = np.random.default_rng(seed)
    eff = min(vocab, 256)
    trans = rng.dirichlet(np.ones(eff) * 0.05, (n_chains, eff))
    out = np.zeros((n_seqs, seq_len + 1), np.int32)
    for i in range(n_seqs):
        c = rng.integers(0, n_chains)
        t = rng.integers(0, eff)
        for j in range(seq_len + 1):
            out[i, j] = t
            t = rng.choice(eff, p=trans[c, t])
    return out
