"""Mini HLO cost model over ``compiled.as_text()``.

XLA's ``cost_analysis()`` counts a ``while`` body once regardless of trip
count (verified empirically) — useless for scan-over-layers models. This
parser rebuilds per-chip cost totals with loop multipliers:

  * computations parsed from the post-partitioning HLO (local shapes);
  * a call graph (while bodies/conditions, fusions, calls, conditionals);
  * while trip counts recovered from the largest integer constant in the
    loop-condition computation (our scans compare an induction counter
    against the layer count — robust for graphs we generate);
  * flops from ``dot``/``convolution`` ops (2 * numel(result) * contracted);
  * HBM bytes from fusion/dot/copy/collective boundaries (operands + result
    read/written once per execution — the XLA fusion-unit memory model);
  * collective bytes per type, with cross-pod classification from
    replica_groups strides.

Everything is per-chip because post-SPMD shapes are local.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_OP_RE = re.compile(r"^(\([^)]*\)|\S+?)\s+([\w\-]+)\(")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body)=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\},?")


def _parse_shape(s: str):
    """'f32[64,256]' -> (dtype, [64,256]); tuples -> list of leaves."""
    out = []
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        shape = [int(x) for x in dims.split(",") if x] if dims else []
        out.append((dt, shape))
    return out


def _nbytes(parsed) -> int:
    total = 0
    for dt, shape in parsed:
        total += _DTYPE_BYTES[dt] * math.prod(shape) if shape else _DTYPE_BYTES[dt]
    return total


@dataclass
class Op:
    name: str
    kind: str
    result_sig: str
    rest: str

    def result_bytes(self) -> int:
        return _nbytes(_parse_shape(self.result_sig))


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    defs: dict = field(default_factory=dict)  # op name -> result signature


def parse_hlo(text: str) -> dict[str, Computation]:
    """Computation headers start at column 0 and end with '{' (op lines are
    indented) — robust against '=' inside /*index=N*/ comments in long
    parameter tuples."""
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped or stripped.startswith("//"):
            continue
        is_header = (not line[:1].isspace() and stripped.endswith("{")
                     and not stripped.startswith("HloModule"))
        if is_header:
            header = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", stripped)
            if header:
                cur = Computation(header.group(1))
                comps[cur.name] = cur
            continue
        if stripped.startswith("}"):
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(stripped)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        om = _OP_RE.match(rhs)
        if not om:
            continue
        result_sig, kind = om.group(1), om.group(2)
        op = Op(name, kind, result_sig, rhs)
        cur.ops.append(op)
        cur.defs[name] = result_sig
    return comps


def _operand_names(op: Op) -> list[str]:
    # operands are inside the first (...) after the op kind
    idx = op.rest.find(op.kind + "(")
    if idx < 0:
        return []
    depth = 0
    start = idx + len(op.kind)
    buf = ""
    for ch in op.rest[start:]:
        if ch == "(":
            depth += 1
            if depth == 1:
                continue
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf += ch
    if "%" in buf:
        # typed-operand dialect: "dot(f32[32,32]{1,0} %lhs, ...)" — shape
        # sigs contain commas, so take the %-prefixed names in order
        return re.findall(r"%([\w.\-]+)", buf)
    names = []
    for tok in buf.split(","):
        tok = tok.strip()
        if re.match(r"^[\w.\-]+$", tok):
            names.append(tok)
    return names


def _operand_bytes(op: Op, comp: Computation) -> int:
    total = 0
    for nm in _operand_names(op):
        sig = comp.defs.get(nm)
        if sig:
            total += _nbytes(_parse_shape(sig))
    return total


def _dot_flops(op: Op, comp: Computation) -> float:
    res = _parse_shape(op.result_sig)
    if not res:
        return 0.0
    numel = math.prod(res[0][1]) if res[0][1] else 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    operands = _operand_names(op)
    if not m or not operands:
        return 2.0 * numel  # fallback
    lhs_sig = comp.defs.get(operands[0], "")
    lhs = _parse_shape(lhs_sig)
    if not lhs:
        return 2.0 * numel
    cdims = [int(x) for x in m.group(1).split(",") if x]
    k = 1
    for d in cdims:
        if d < len(lhs[0][1]):
            k *= lhs[0][1][d]
    return 2.0 * numel * k


def _conv_flops(op: Op, comp: Computation) -> float:
    res = _parse_shape(op.result_sig)
    operands = _operand_names(op)
    if not res or len(operands) < 2:
        return 0.0
    numel = math.prod(res[0][1]) if res[0][1] else 1
    ksig = _parse_shape(comp.defs.get(operands[1], ""))
    if not ksig:
        return 2.0 * numel
    kshape = ksig[0][1]
    # output numel * 2 * (kernel spatial x input feature) = kernel numel / out_feat
    # approximate: 2 * out_numel * prod(kernel)/out_channels
    out_ch = kshape[-1] if kshape else 1
    k = math.prod(kshape) / max(out_ch, 1)
    return 2.0 * numel * k


def _fusion_root(op: Op, comps: dict):
    for cname in _CALLS_RE.findall(op.rest):
        c = comps.get(cname)
        if c and c.ops:
            return c.ops[-1], c
    return None, None


def _fusion_is_dus(op: Op, comps: dict) -> bool:
    root, _ = _fusion_root(op, comps)
    return root is not None and root.kind == "dynamic-update-slice"


def _dus_update_bytes(op: Op, comps: dict) -> int:
    root, c = _fusion_root(op, comps)
    if root is None:
        return 0
    ops_ = _operand_names(root)
    if len(ops_) > 1:
        sig = c.defs.get(ops_[1])
        if sig:
            return _nbytes(_parse_shape(sig))
    return 0


def _trip_count(cond: Computation) -> int:
    best = 1
    for op in cond.ops:
        if op.kind == "constant":
            m = re.search(r"constant\((\d+)\)", op.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# per-chip traffic factor x (operand|result) bytes (ring collective model)
_COLL_COST = {
    "all-reduce": ("operand", 2.0),
    "all-gather": ("result", 1.0),
    "reduce-scatter": ("operand", 1.0),
    "all-to-all": ("operand", 1.0),
    "collective-permute": ("operand", 1.0),
}

_FUSION_BOUNDARY = ("fusion", "dot", "convolution", "copy", "scatter",
                    "gather", "dynamic-slice", "dynamic-update-slice",
                    "sort", "reduce", "transpose", "broadcast", "iota",
                    "concatenate", "reshape", "slice", "pad", "select")

# ops whose results typically stay in registers / get fused on TPU; we count
# HBM traffic only at fusion boundaries:
_HBM_OPS = ("fusion", "dot", "convolution", "copy", "scatter", "sort",
            "dynamic-update-slice") + _COLLECTIVES


@dataclass
class CostSummary:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: dict = field(default_factory=dict)  # type -> weighted bytes
    collective_raw: dict = field(default_factory=dict)  # type -> operand bytes
    cross_pod_bytes: float = 0.0
    trip_counts: dict = field(default_factory=dict)
    top_flops: list = field(default_factory=list)  # (flops, mult, name, meta)
    top_hbm: list = field(default_factory=list)
    top_coll: list = field(default_factory=list)

    @property
    def total_collective(self) -> float:
        return sum(self.collective_bytes.values())

    def keep_top(self, n: int = 20):
        self.top_flops = sorted(self.top_flops, reverse=True)[:n]
        self.top_hbm = sorted(self.top_hbm, reverse=True)[:n]
        self.top_coll = sorted(self.top_coll, reverse=True)[:n]


def _is_cross_pod(op: Op, devices_per_pod: int) -> bool:
    m = _GROUPS_RE.search(op.rest)
    if not m:
        m2 = re.search(r"replica_groups=\{\{([^}]*)\}", op.rest)
        if not m2:
            return False
        ids = [int(x) for x in m2.group(1).split(",") if x.strip().isdigit()]
        return bool(ids) and (max(ids) // devices_per_pod != min(ids) // devices_per_pod)
    first = m.group(1).split("}")[0].strip("{}")
    ids = [int(x) for x in first.split(",") if x.strip().lstrip("-").isdigit()]
    if not ids:
        return False
    return max(ids) // devices_per_pod != min(ids) // devices_per_pod


def analyze(text: str, devices_per_pod: int = 256) -> CostSummary:
    comps = parse_hlo(text)
    entry = None
    for name in comps:
        if name.startswith("main") or ".main" in name or name.startswith("jit"):
            entry = name
    if entry is None and comps:
        entry = list(comps)[-1]

    summary = CostSummary()
    visited_mult: dict[str, float] = {}

    def walk(comp_name: str, mult: float):
        comp = comps.get(comp_name)
        if comp is None:
            return
        visited_mult[comp_name] = visited_mult.get(comp_name, 0.0) + mult
        for op in comp.ops:
            if op.kind == "while":
                cm = _COND_RE.search(op.rest)
                bm = re.search(r"body=%?([\w.\-]+)", op.rest)
                trips = 1
                if cm and cm.group(1) in comps:
                    trips = _trip_count(comps[cm.group(1)])
                summary.trip_counts[op.name] = trips
                if bm:
                    walk(bm.group(1), mult * trips)
                if cm:
                    walk(cm.group(1), mult * trips)
                continue
            if op.kind in ("fusion", "call", "custom-call", "map", "reduce",
                           "reduce-window", "scatter", "select-and-scatter",
                           "conditional"):
                for cname in _CALLS_RE.findall(op.rest):
                    if cname in comps and cname != comp_name:
                        walk(cname, mult)
            # flops
            if op.kind == "dot":
                f = mult * _dot_flops(op, comp)
                summary.flops += f
                meta = re.search(r'op_name="([^"]*)"', op.rest)
                summary.top_flops.append(
                    (f, mult, op.name, op.result_sig,
                     meta.group(1)[-90:] if meta else ""))
            elif op.kind == "convolution":
                summary.flops += mult * _conv_flops(op, comp)
            # collectives
            if op.kind in _COLLECTIVES:
                basis, factor = _COLL_COST[op.kind]
                opb = _operand_bytes(op, comp)
                rb = op.result_bytes()
                raw = opb if basis == "operand" else rb
                if raw == 0:
                    raw = max(opb, rb)
                # XLA-CPU promotes bf16 all-reduce accumulation to f32
                # (to_apply=%..._promoted); on TPU the wire stays bf16.
                if "promoted" in op.rest and op.kind == "all-reduce":
                    raw *= 0.5
                summary.collective_raw[op.kind] = (
                    summary.collective_raw.get(op.kind, 0.0) + mult * raw)
                summary.collective_bytes[op.kind] = (
                    summary.collective_bytes.get(op.kind, 0.0) + mult * factor * raw)
                if _is_cross_pod(op, devices_per_pod):
                    summary.cross_pod_bytes += mult * factor * raw
                meta = re.search(r'op_name="([^"]*)"', op.rest)
                summary.top_coll.append(
                    (mult * factor * raw, mult, op.name, op.result_sig,
                     meta.group(1)[-90:] if meta else ""))
            # HBM traffic model: every boundary op writes its result once;
            # reads are counted only for dot (MXU streams both operands) —
            # on TPU the elementwise producers/consumers fuse, so counting
            # operand bytes of every fusion double-counts each tensor.
            if op.kind in _HBM_OPS:
                if op.kind == "dynamic-update-slice":
                    # in-place update: only the written slice moves (the big
                    # buffer is aliased), operands[1] is the update
                    ops_ = _operand_names(op)
                    upd = _nbytes(_parse_shape(comp.defs.get(ops_[1], ""))) \
                        if len(ops_) > 1 else 0
                    b = mult * 2 * upd
                elif op.kind == "fusion" and _fusion_is_dus(op, comps):
                    # fused in-place scan-stack write: slice bytes, not buffer
                    b = mult * 2 * _dus_update_bytes(op, comps)
                elif op.kind in ("dot", "convolution"):
                    b = mult * (op.result_bytes() + _operand_bytes(op, comp))
                else:
                    b = mult * op.result_bytes()
                summary.hbm_bytes += b
                meta = re.search(r'op_name="([^"]*)"', op.rest)
                summary.top_hbm.append(
                    (b, mult, op.name, op.result_sig,
                     meta.group(1)[-90:] if meta else ""))

    if entry:
        walk(entry, 1.0)
    summary.keep_top()
    return summary
