"""Generate the EXPERIMENTS.md roofline tables from dry-run artifacts, and
render the privacy ledger's per-silo spend reports for the admin plane."""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import SHAPES, get_config, shape_applicability

DRYRUN = Path("experiments/dryrun")


# ---------------------------------------------------------------------------
# Privacy-ledger spend reports (core/privacy/ledger.py spend_report dicts)


def _eps(x) -> str:
    return "inf" if x is None else f"{x:.4f}"


def verify_spend_report(report: dict, attestation, *, component: str = "admin",
                        expected_measurement: str = None) -> bool:
    """Verify a ledger-signed spend report (``Admin.sign_spend_report``).

    Two checks, both required. (1) The MAC: the signing key is derived from
    the hardware-root signature over the signer's attestation report, which
    is *not* carried in the JSON — the verifier recomputes it through
    ``attestation`` (the root of trust) from the embedded identity claim,
    so re-signing a tampered body requires the attestation root key.
    (2) The identity: the claimed signer must be the component the owners
    trust — its name must match ``component`` and, when
    ``expected_measurement`` is given (the service's
    ``expected_measurement()``), its measured code+config hash too. Without
    (2), *any* attested party (e.g. a data handler) could re-sign a
    tampered body under its own identity. Returns False for
    missing/invalid signatures or mismatched signers."""
    import hmac as hmac_mod

    from repro.core.tee.channels import spend_report_mac

    sig = report.get("signature")
    if not isinstance(sig, dict) or "hmac" not in sig or "signer" not in sig:
        return False
    signer = sig["signer"]
    try:
        if signer["component"] != component:
            return False
        if expected_measurement is not None \
                and signer["code_measurement"] != expected_measurement:
            return False
        att = attestation.issue(signer["component"],
                                signer["code_measurement"],
                                signer["policy_hash"], signer["nonce"])
    except (KeyError, TypeError):
        return False
    body = {k: v for k, v in report.items() if k != "signature"}
    expect = spend_report_mac(body, att.signature)
    return hmac_mod.compare_digest(expect, sig["hmac"])


def privacy_spend_table(report: dict, attestation=None) -> str:
    """Markdown table for one :meth:`PrivacyLedger.spend_report` dict: one
    row per silo with its own participation history, spend and verdict.
    With ``attestation`` (the session's attestation service), a ledger
    signature is verified and its status rendered; without it the signature
    is only surfaced (verification needs the root of trust)."""
    # round-trip telemetry rides in the signed body only when the trainer
    # observed it (SiloTelemetry) — the column appears iff any silo has it
    with_rt = any(s.get("avg_round_trip_ms") is not None
                  for s in report["silos"])
    rt_head = " rt (ms) |" if with_rt else ""
    rt_rule = "---|" if with_rt else ""
    lines = [
        f"mode={report['mode']} sigma={report['sigma']:.4g} "
        f"delta={report['delta']:.1e} lam={report['lam']:.2f} "
        f"steps={report['steps']} "
        f"global eps={_eps(report['epsilon_global'])}",
        "",
        "| silo | steps in | steps out | epsilon | budget | remaining "
        f"| status |{rt_head}",
        f"|---|---|---|---|---|---|---|{rt_rule}",
    ]
    for s in report["silos"]:
        budget = "—" if s["budget"] is None else f"{s['budget']:.4f}"
        remaining = "—" if s["remaining"] is None else f"{s['remaining']:.4f}"
        status = "EXHAUSTED" if s["exhausted"] else "ok"
        rt = ""
        if with_rt:
            ms = s.get("avg_round_trip_ms")
            rt = " — |" if ms is None else f" {ms:.3f} |"
        lines.append(
            f"| {s['silo']} | {s['steps_participated']} "
            f"| {s['steps_sat_out']} | {_eps(s['epsilon'])} "
            f"| {budget} | {remaining} | {status} |{rt}")
    for e in report.get("exclusions", []):
        lines.append(f"silo {e['silo']} excluded at step {e['step']} "
                     f"(eps {_eps(e['epsilon'])} >= budget "
                     f"{_eps(e['budget'])})")
    sig = report.get("signature")
    if sig is not None:
        signer = sig.get("signer", {})
        status = "present" if attestation is None else \
            ("VERIFIED" if verify_spend_report(report, attestation)
             else "INVALID")
        lines.append(
            f"signature: {status} — {sig.get('scheme')} by "
            f"{signer.get('component', '?')} "
            f"(measurement {signer.get('code_measurement', '')[:12]}…); "
            f"verify with verify_spend_report(report, attestation_service)")
    return "\n".join(lines)


def privacy_spend_summary(path: str | Path) -> str:
    """Render a spend-report JSON file (as written by
    ``launch/train.py --spend-report``)."""
    return privacy_spend_table(json.loads(Path(path).read_text()))


# ---------------------------------------------------------------------------
# Wire-bench tables (BENCH_wire.json, benchmarks/wire_bench.py)


def wire_cost_split(results: dict) -> dict:
    """Least-squares split of the silo-count sweep into fixed-per-round and
    marginal-per-silo cost: us_per_round(n) ~= intercept + slope * n over
    the ``wire/sweep_n*`` rows. The intercept is the amortizable protocol
    floor (one dispatch graph, one batch HMAC, one broadcast encode, one
    admin closing row); the slope is the irreducible per-party cost (one
    sandboxed grad + mask + seal per silo). Needs >= 2 sweep rows."""
    import numpy as np

    pts = sorted((v["n_silos"], v["us_per_round"])
                 for k, v in results.items() if k.startswith("wire/sweep_n"))
    if len(pts) < 2:
        raise ValueError(
            f"cost split needs >= 2 wire/sweep_n* rows, found {len(pts)}")
    ns = np.array([p[0] for p in pts], float)
    ts = np.array([p[1] for p in pts], float)
    # weight by 1/t: round time spans orders of magnitude across the sweep,
    # so an unweighted fit is pure leverage from the largest n and can miss
    # the small-n rows (where the fixed cost actually shows) by tens of
    # percent; minimizing RELATIVE residuals treats every n as one sample
    # of the same cost model
    slope, intercept = np.polyfit(ns, ts, 1, w=1.0 / ts)
    fit = intercept + slope * ns
    resid = (ts - fit) / ts
    return {"intercept_us": float(intercept), "slope_us_per_silo": float(slope),
            "rows": [{"n_silos": int(n), "us_per_round": t,
                      "fit_us": float(f), "resid_frac": float(r)}
                     for n, t, f, r in zip(ns, ts, fit, resid)],
            "max_resid_frac": float(abs(resid).max())}


def wire_bench_table(path: str | Path = "BENCH_wire.json") -> str:
    """Markdown summary of a wire-bench artifact: the sweep's fixed/per-silo
    cost split and the pipelined-vs-speculative round comparison per
    payload."""
    results = json.loads(Path(path).read_text())
    lines = []
    try:
        split = wire_cost_split(results)
    except ValueError as e:
        lines.append(f"(no cost split: {e})")
    else:
        lines += [
            f"cost split (fit over wire/sweep_n*): fixed "
            f"{split['intercept_us']:.0f}us/round + "
            f"{split['slope_us_per_silo']:.1f}us/silo "
            f"(max residual {split['max_resid_frac'] * 100:.1f}%)",
            "",
            "| n_silos | us/round | per-silo us | linear fit | resid |",
            "|---|---|---|---|---|",
        ]
        for r in split["rows"]:
            lines.append(
                f"| {r['n_silos']} | {r['us_per_round']:.0f} "
                f"| {r['us_per_round'] / r['n_silos']:.0f} "
                f"| {r['fit_us']:.0f} | {r['resid_frac'] * 100:+.1f}% |")
    scheds = ("serial", "pipelined", "speculative")
    payloads = sorted(
        {k.rsplit("_", 1)[-1] for k in results
         if k.startswith("wire/round_packed_")},
        key=lambda p: results[f"wire/round_packed_pipelined_{p}"]
        ["payload_floats"])
    if payloads:
        lines += ["", "| payload | " + " | ".join(scheds)
                  + " | spec vs pipelined |", "|---|---|---|---|---|"]
        for p in payloads:
            row = {s: results.get(f"wire/round_packed_{s}_{p}")
                   for s in scheds}
            cells = [f"{row[s]['us_per_round']:.0f}us" if row[s] else "—"
                     for s in scheds]
            ratio = "—"
            if row["pipelined"] and row["speculative"]:
                ratio = (f"{row['pipelined']['us_per_round'] / row['speculative']['us_per_round']:.2f}x")
            lines.append(f"| {p} | " + " | ".join(cells) + f" | {ratio} |")
    return "\n".join(lines)


def load(mesh: str) -> dict:
    out = {}
    d = DRYRUN / mesh
    if not d.exists():
        return out
    for f in d.glob("*.json"):
        rec = json.loads(f.read_text())
        out[(rec["arch"], rec["shape"])] = rec
    return out


def fmt_bytes(b):
    return f"{b / 1e9:.2f}GB"


def roofline_table(mesh: str) -> str:
    recs = load(mesh)
    lines = [
        "| arch | shape | dominant | t_compute | t_memory | t_collective "
        "| roofline frac | useful flops | HBM/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    from repro.configs import ARCH_IDS
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for sname, shape in SHAPES.items():
            ok, reason = shape_applicability(cfg, shape)
            if not ok:
                lines.append(f"| {arch} | {sname} | — | — | — | — | N/A | — | "
                             f"{reason} |")
                continue
            rec = recs.get((arch, sname))
            if rec is None or rec.get("status") != "ok":
                status = rec.get("status", "missing") if rec else "missing"
                lines.append(f"| {arch} | {sname} | {status} | | | | | | |")
                continue
            r = rec["roofline"]
            mem = rec["memory"]
            hbm = (mem["argument_bytes_per_dev"] + mem["temp_bytes_per_dev"]
                   + mem["output_bytes_per_dev"] - mem["alias_bytes_per_dev"])
            lines.append(
                f"| {arch} | {sname} | **{r['dominant']}** "
                f"| {r['t_compute_s']:.2e}s | {r['t_memory_s']:.2e}s "
                f"| {r['t_collective_s']:.2e}s | {r['roofline_fraction']:.3f} "
                f"| {r['useful_flops_ratio']:.2f} | {fmt_bytes(hbm)} |")
    return "\n".join(lines)


def dryrun_summary(mesh: str) -> str:
    recs = load(mesh)
    ok = sum(1 for r in recs.values() if r.get("status") == "ok")
    lines = [f"{ok}/{len(recs)} cells compiled.", "",
             "| arch | shape | params | compile | collective mix (weighted bytes/chip) | cross-pod |",
             "|---|---|---|---|---|---|"]
    for (arch, sname), rec in sorted(recs.items()):
        if rec.get("status") != "ok":
            continue
        hc = rec["hlo_cost"]
        mix = ", ".join(f"{k.replace('all-', 'a')}:{v:.1e}"
                        for k, v in sorted(hc["collective_bytes_weighted"].items()))
        lines.append(
            f"| {arch} | {sname} | {rec['params_B']:.1f}B "
            f"| {rec['compile_s']:.0f}s | {mix} "
            f"| {hc['cross_pod_bytes']:.1e} |")
    return "\n".join(lines)


if __name__ == "__main__":
    import sys
    kind = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    if kind == "privacy":
        # python -m repro.analysis.report privacy SPEND_report.json
        print(privacy_spend_summary(sys.argv[2]))
    elif kind == "wire":
        # python -m repro.analysis.report wire [BENCH_wire.json]
        print(wire_bench_table(sys.argv[2] if len(sys.argv) > 2
                               else "BENCH_wire.json"))
    else:
        mesh = sys.argv[2] if len(sys.argv) > 2 else "single"
        print(roofline_table(mesh) if kind == "roofline" else dryrun_summary(mesh))
